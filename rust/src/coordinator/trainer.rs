//! The training loop.
//!
//! Two step modes share one interface:
//!
//! - **Host**: run the `grad` artifact, then a Rust [`Optimizer`] — the
//!   path every roster optimizer and every grid-search experiment uses.
//! - **Fused**: run a `train_*` artifact whose XLA graph contains both
//!   the backward pass and the L1 Pallas optimizer kernel — the
//!   production hot path.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::TrainConfig;
use crate::data::{Batch, Batcher, Corpus, SyntheticSpec};
use crate::dist::{self, CommStats, DistOptions, DistTrainer};
use crate::optim::{self, AdamMini, Optimizer, ReduceOp, Schedule};
use crate::partition::Strategy;
use crate::runtime::{Engine, ModelRuntime};
use crate::runtime::model::FusedTrainer;
use crate::telemetry::{Event, EventBus, Telemetry};
use crate::tensor::Tensor;
use crate::util::csv::Csv;
use crate::util::timer::Timer;

/// Per-step record.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub val_loss: Option<f32>,
}

/// Full run record.
#[derive(Debug, Clone, Default)]
pub struct RunHistory {
    pub name: String,
    pub steps: Vec<StepLog>,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub opt_state_bytes: usize,
}

impl RunHistory {
    pub fn final_train_loss(&self) -> f32 {
        self.steps.last().map(|s| s.loss).unwrap_or(f32::NAN)
    }

    /// Last recorded validation loss.
    pub fn final_val_loss(&self) -> f32 {
        self.steps
            .iter()
            .rev()
            .find_map(|s| s.val_loss)
            .unwrap_or(f32::NAN)
    }

    /// Mean training loss over the last `k` logged steps (noise-robust).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return f32::NAN;
        }
        let tail = &self.steps[n.saturating_sub(k)..];
        tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32
    }

    /// True if any step showed a spike: loss > `factor` × running min.
    pub fn has_spike(&self, factor: f32) -> bool {
        let mut run_min = f32::MAX;
        for s in &self.steps {
            if s.loss.is_nan() || (run_min < f32::MAX
                && s.loss > factor * run_min) {
                return true;
            }
            run_min = run_min.min(s.loss);
        }
        false
    }

    /// Write the loss curve to `results/<name>.csv`.
    pub fn write_csv(&self, dir: &str) -> Result<std::path::PathBuf> {
        let mut csv = Csv::create(
            format!("{dir}/{}.csv", self.name),
            &["step", "loss", "lr", "val_loss"])?;
        for s in &self.steps {
            csv.row(&[s.step as f64, s.loss as f64, s.lr as f64,
                      s.val_loss.map(|v| v as f64).unwrap_or(f64::NAN)])?;
        }
        csv.flush()?;
        Ok(csv.path)
    }
}

/// Which stepping engine a trainer uses.
pub enum TrainerMode {
    Host(Box<dyn Optimizer>),
    Fused(FusedTrainer),
    /// Data-parallel over in-process workers (`workers > 1`).
    /// `replicated` is `Some` when the optimizer is not ZeRO-1
    /// shardable: gradients still all-reduce across workers, and the
    /// per-replica update (identical on every worker) executes once.
    Dist {
        dist: DistTrainer,
        replicated: Option<Box<dyn Optimizer>>,
    },
}

/// The Fig 15 reduce-op names → [`ReduceOp`].
fn parse_reduce(name: &str) -> Result<ReduceOp> {
    Ok(match name {
        "mean" => ReduceOp::Mean,
        "max" => ReduceOp::Max,
        "min" => ReduceOp::Min,
        "l1norm" => ReduceOp::L1Norm,
        "l2norm" => ReduceOp::L2Norm,
        other => bail!("unknown reduce op {other:?}"),
    })
}

/// Partition strategy implied by an `adam_mini*` roster name.
fn mini_strategy(optimizer: &str) -> Strategy {
    match optimizer {
        "adam_mini_default" => Strategy::Default,
        "adam_mini_value_whole" => Strategy::ValueWhole,
        _ => Strategy::Hessian,
    }
}

/// The single-replica host optimizer for a config (the pre-dist logic,
/// shared by the host path and the dist replicated fallback).
fn build_host_optimizer(cfg: &TrainConfig, hp: optim::Hyper,
                        params: &[Tensor], rt: &ModelRuntime)
    -> Result<Box<dyn Optimizer>> {
    if cfg.optimizer.starts_with("adam_mini") && cfg.reduce_op != "mean" {
        // Fig 15 ablation path.
        let op = parse_reduce(&cfg.reduce_op)?;
        let spec = rt
            .mm
            .meta()
            .spec_for(params, mini_strategy(&cfg.optimizer))?;
        Ok(Box::new(AdamMini::new(hp, spec, op)))
    } else {
        optim::by_name(&cfg.optimizer, hp, params, &rt.mm.meta())
    }
}

/// A configured training run.
pub struct Trainer<'e> {
    pub rt: ModelRuntime<'e>,
    pub params: Vec<Tensor>,
    pub mode: TrainerMode,
    pub schedule: Schedule,
    batcher: Batcher,
    val_batches: Vec<Batch>,
    cfg: TrainConfig,
    step: usize,
    /// Optional parameter-snapshot recording (Fig 9b trajectories):
    /// (every_k, snapshots).
    pub snapshots: Option<(usize, Vec<Vec<Tensor>>)>,
    /// Attached observer; the publisher bus is cached separately so
    /// the step path never touches the telemetry mutex.
    telemetry: Option<Arc<Mutex<Telemetry>>>,
    bus: Option<Arc<EventBus>>,
}

/// Publish onto an optionally-attached bus (no-op when detached).
fn pub_ev(bus: &Option<Arc<EventBus>>, event: Event) {
    if let Some(b) = bus {
        b.publish(event);
    }
}

impl<'e> Trainer<'e> {
    /// Build a trainer from a config against a loaded engine.
    pub fn from_config(engine: &'e Engine, cfg: &TrainConfig)
        -> Result<Trainer<'e>> {
        let rt = ModelRuntime::new(engine, &cfg.model)?;
        let params = rt.init_params(cfg.seed);
        let corpus = make_corpus(&rt, cfg)?;
        let (batcher, val_batches) = split_batches(
            corpus, rt.mm.batch_size, rt.mm.seq_len, cfg.seed)?;
        let schedule = cfg.schedule_for(cfg.steps)?;
        let hp = optim::Hyper {
            ..engine.manifest.hyper()
        };

        // Resolve the kernel dispatch policy BEFORE any optimizer is
        // constructed: every optimizer caches its scalar/vector
        // dispatch from the thread-local policy at build time.
        optim::kernels::set_policy(
            optim::kernels::SimdPolicy::parse(&cfg.simd)?);
        if cfg.clip > 0.0 && (cfg.workers > 1 || cfg.fused) {
            bail!("clip={} needs the host optimizer path: the global \
                   grad-norm pass folds into the in-process fused \
                   kernels only (run workers=1 without fused=true)",
                  cfg.clip);
        }
        let compress = dist::CodecSpec::parse(&cfg.compress)?;
        if !compress.is_none() && cfg.workers <= 1 {
            bail!("compress={} needs the dist engine: gradient codecs \
                   sit under the worker collectives (run with \
                   workers > 1)",
                  cfg.compress);
        }

        let mode = if cfg.fused && cfg.workers <= 1 {
            let key = match cfg.optimizer.as_str() {
                "adamw" => "train_adamw",
                "adam_mini" => "train_adam_mini",
                "adam_mini_default" => "train_adam_mini_default",
                other => bail!("no fused artifact for optimizer {other:?}"),
            };
            TrainerMode::Fused(rt.fused(key)?)
        } else if cfg.workers > 1 {
            if cfg.fused {
                // The XLA train_* artifact path is single-worker; a
                // multi-worker fused run steps its shards through the
                // in-process fused SIMD kernels instead of erroring.
                println!(
                    "fused=true with workers={}: the XLA train_* \
                     artifact path is single-worker, so this run uses \
                     the in-process fused SIMD step kernels (run \
                     workers=1 to use the artifact)", cfg.workers);
            }
            // ZeRO-2 implies state sharding; both degrade to
            // replicated mode for non-shardable optimizers.
            let can_shard = dist::shardable(&cfg.optimizer);
            let sharded = (cfg.zero1 || cfg.zero2) && can_shard;
            let spec = if cfg.optimizer.starts_with("adam_mini") {
                Some(rt.mm.meta().spec_for(
                    &params, mini_strategy(&cfg.optimizer))?)
            } else {
                None
            };
            let dist = DistTrainer::new(&params, DistOptions {
                workers: cfg.workers,
                bucket_kb: cfg.bucket_kb,
                zero1: sharded,
                zero2: cfg.zero2 && can_shard,
                bucket_step: cfg.bucket_step,
                optimizer: cfg.optimizer.clone(),
                reduce: parse_reduce(&cfg.reduce_op)?,
                hp,
                spec,
                compute: dist::ComputeModel {
                    step_ns_per_elem:
                        optim::kernels::measured_step_ns_per_elem(),
                    ..Default::default()
                },
                transport: dist::parse_transport(
                    &cfg.transport, &cfg.fault, cfg.fault_seed)?,
                compress,
                ..Default::default()
            })?;
            let replicated = if sharded {
                None
            } else {
                Some(build_host_optimizer(cfg, hp, &params, &rt)?)
            };
            TrainerMode::Dist { dist, replicated }
        } else {
            TrainerMode::Host(build_host_optimizer(cfg, hp, &params,
                                                   &rt)?)
        };

        Ok(Trainer {
            rt,
            params,
            mode,
            schedule,
            batcher,
            val_batches,
            cfg: cfg.clone(),
            step: 0,
            snapshots: None,
            telemetry: None,
            bus: None,
        })
    }

    /// Attach a telemetry subscriber: caches its publisher bus and
    /// threads the handle into every emitting layer (dist workers,
    /// the comm ledger, the artifact engine). [`Trainer::train`]
    /// pumps the subscriber once per step.
    pub fn attach_telemetry(&mut self, t: Arc<Mutex<Telemetry>>) {
        let bus = t.lock().unwrap_or_else(|e| e.into_inner()).bus();
        if let TrainerMode::Dist { dist, .. } = &mut self.mode {
            dist.attach_bus(Arc::clone(&bus));
        }
        self.rt.engine.attach_bus(Arc::clone(&bus));
        self.bus = Some(bus);
        self.telemetry = Some(t);
    }

    /// Enable parameter snapshots every `k` steps (Fig 9b).
    pub fn record_snapshots(&mut self, every: usize) {
        self.snapshots = Some((every, vec![self.params.clone()]));
    }

    /// Refresh host params from the fused trainer's literal state.
    fn sync_params(&mut self) -> Result<()> {
        if let TrainerMode::Fused(fused) = &self.mode {
            fused.sync_params(&mut self.params)?;
        }
        Ok(())
    }

    /// Validation loss averaged over the held-out batches (syncs the
    /// fused state first).
    pub fn validate(&mut self) -> Result<f32> {
        self.sync_params()?;
        let mut acc = 0.0;
        for b in &self.val_batches {
            acc += self.rt.eval_loss(&self.params, b)?;
        }
        Ok(acc / self.val_batches.len() as f32)
    }

    /// One training step; returns the (averaged) batch loss.
    pub fn step_once(&mut self) -> Result<f32> {
        self.step += 1;
        let lr = self.schedule.lr(self.step);
        let step = self.step as u64;
        // Dist mode emits its own step brackets from inside the
        // worker engine; the host/fused paths bracket here.
        let dist_mode = matches!(self.mode, TrainerMode::Dist { .. });
        if !dist_mode {
            pub_ev(&self.bus, Event::StepBegin {
                step,
                n_micro: self.cfg.grad_accum.max(1),
                workers: 1,
            });
        }
        let t0 = Instant::now();
        let loss = match &mut self.mode {
            TrainerMode::Fused(fused) => {
                // Fast path: state stays literal-resident; host params
                // are refreshed lazily (validate / snapshots / end).
                let batch = self.batcher.next_batch();
                fused.step_device(&self.params, &batch, lr)?
            }
            TrainerMode::Host(opt) => {
                // Gradient accumulation: micro-batch grads sum in
                // place. The 1/accum average and the global-norm clip
                // factor fold into the fused update sweep as a single
                // per-element gradient scale — no separate normalize
                // or clip pass ever writes the gradient buffers.
                let accum = self.cfg.grad_accum.max(1);
                let mut total_loss = 0.0;
                let mut grads: Option<Vec<Tensor>> = None;
                for _ in 0..accum {
                    let batch = self.batcher.next_batch();
                    let (loss, g) = self.rt.grad(&self.params, &batch)?;
                    total_loss += loss;
                    grads = Some(match grads {
                        None => g,
                        Some(mut acc) => {
                            for (a, b) in acc.iter_mut().zip(&g) {
                                a.axpy(1.0, b);
                            }
                            acc
                        }
                    });
                }
                let grads = grads.unwrap();
                let inv = 1.0 / accum as f32;
                let gscale =
                    inv * clip_scale(&grads, inv, self.cfg.clip as f32);
                opt.step_scaled(&mut self.params, &grads, lr, gscale);
                total_loss / accum as f32
            }
            TrainerMode::Dist { dist, replicated } => {
                // The GLOBAL batch is `grad_accum` micro-batches drawn
                // from the same stream in the same order for every
                // world size; micro-batch i goes to worker i % N. That
                // makes the N-worker run consume exactly the data the
                // 1-worker run does — the loss-equivalence invariant.
                let accum = self.cfg.grad_accum.max(1);
                let mut total_loss = 0.0;
                let n = dist.workers();
                let reduced = if self.cfg.overlap {
                    // Streaming pipeline: each readiness bucket's
                    // collective launches while later gradients are
                    // still being produced.
                    let mut stream = dist.begin_step(accum, lr);
                    for i in 0..accum {
                        let batch = self.batcher.next_batch();
                        let l = self.rt.grad_streamed(
                            &self.params, &batch,
                            |j, g| stream.push_grad(i, j, &g))?;
                        total_loss += l;
                        pub_ev(&self.bus, Event::LossReported {
                            step,
                            rank: (i % n) as i64,
                            loss: l as f64,
                            lr: lr as f64,
                        });
                    }
                    stream.finish(&mut self.params)?
                } else {
                    let mut local = dist.grad_buffers();
                    for i in 0..accum {
                        let batch = self.batcher.next_batch();
                        let (loss, g) =
                            self.rt.grad(&self.params, &batch)?;
                        total_loss += loss;
                        pub_ev(&self.bus, Event::LossReported {
                            step,
                            rank: (i % n) as i64,
                            loss: loss as f64,
                            lr: lr as f64,
                        });
                        dist.layout().accumulate(&mut local[i % n], &g);
                    }
                    dist.step(&mut self.params, local, accum, lr)?
                };
                if let (Some(opt), Some(grads)) = (replicated, reduced) {
                    opt.step(&mut self.params, &grads, lr);
                }
                total_loss / accum as f32
            }
        };
        if !dist_mode {
            pub_ev(&self.bus, Event::StepEnd {
                step,
                wall_ns: t0.elapsed().as_secs_f64() * 1e9,
            });
        }
        // Cluster-level loss (rank -1): this is the number `repro top`
        // sparklines and the run history record.
        pub_ev(&self.bus, Event::LossReported {
            step,
            rank: -1,
            loss: loss as f64,
            lr: lr as f64,
        });
        if self.snapshots.as_ref().is_some_and(
            |(every, _)| self.step % every == 0)
        {
            self.sync_params()?;
            if let Some((_, snaps)) = &mut self.snapshots {
                snaps.push(self.params.clone());
            }
        }
        Ok(loss)
    }

    /// Run the configured number of steps, logging per `log_every`.
    pub fn train(&mut self, quiet: bool) -> Result<RunHistory> {
        let timer = Timer::start();
        let mut hist = RunHistory {
            name: format!("{}_{}_s{}", self.cfg.model, self.cfg.optimizer,
                          self.cfg.seed),
            ..Default::default()
        };
        let tokens_per_step = (self.rt.mm.batch_size * self.rt.mm.seq_len
            * self.cfg.grad_accum.max(1)) as f64;
        for _ in 0..self.cfg.steps {
            let loss = self.step_once()?;
            // Drain the bus once per step (skip, never block, if an
            // external observer holds the lock right now).
            if let Some(t) = &self.telemetry {
                if let Ok(mut t) = t.try_lock() {
                    t.pump()?;
                }
            }
            let lr = self.schedule.lr(self.step);
            let log_now = self.step % self.cfg.log_every.max(1) == 0
                || self.step == 1 || self.step == self.cfg.steps;
            if log_now {
                let val = if self.cfg.eval_every > 0
                    && (self.step % self.cfg.eval_every == 0
                        || self.step == self.cfg.steps)
                {
                    Some(self.validate()?)
                } else {
                    None
                };
                if !quiet {
                    match val {
                        Some(v) => println!(
                            "step {:>6}  loss {:.4}  val {:.4}  lr {:.2e}",
                            self.step, loss, v, lr),
                        None => println!(
                            "step {:>6}  loss {:.4}  lr {:.2e}",
                            self.step, loss, lr),
                    }
                }
                hist.steps.push(StepLog {
                    step: self.step, loss, lr, val_loss: val });
            }
            if !loss.is_finite() {
                if !quiet {
                    println!("step {}: loss diverged ({loss}); stopping",
                             self.step);
                }
                hist.steps.push(StepLog {
                    step: self.step, loss, lr, val_loss: None });
                break;
            }
        }
        self.sync_params()?;
        hist.wall_secs = timer.secs();
        hist.tokens_per_sec =
            self.step as f64 * tokens_per_step / hist.wall_secs;
        hist.opt_state_bytes = match &self.mode {
            TrainerMode::Host(o) => o.state_bytes(),
            TrainerMode::Fused(f) => f.state_bytes(),
            TrainerMode::Dist { dist, replicated } => replicated
                .as_ref()
                .map(|o| o.state_bytes())
                .unwrap_or_else(|| dist.state_bytes()),
        };
        Ok(hist)
    }

    pub fn current_step(&self) -> usize {
        self.step
    }

    /// The dist engine's traffic ledger (None for single-worker runs).
    pub fn comm_stats(&self) -> Option<Arc<CommStats>> {
        match &self.mode {
            TrainerMode::Dist { dist, .. } => Some(dist.stats().clone()),
            _ => None,
        }
    }

    /// Modeled timeline of the last streamed step (None unless the
    /// run is dist with `overlap=true` and has stepped).
    pub fn step_timing(&self) -> Option<dist::StepTiming> {
        match &self.mode {
            TrainerMode::Dist { dist, .. } => dist.last_step_timing(),
            _ => None,
        }
    }

    /// Save parameters AND optimizer state (a resumable checkpoint,
    /// written as a named [`crate::optim::StateDict`]). Sharded state
    /// is collected through the transport (accounted as `state_sync`
    /// traffic). The fused path saves parameters only — its state is
    /// device-resident with no import ABI (inspect it with
    /// [`crate::runtime::model::FusedTrainer::state_dict`]).
    pub fn save_run_checkpoint(&mut self, path: impl AsRef<std::path::Path>)
        -> Result<()> {
        self.sync_params()?;
        let state = match &mut self.mode {
            TrainerMode::Host(o) => o.state_dict(),
            TrainerMode::Fused(_) => crate::optim::StateDict::new(),
            TrainerMode::Dist { dist, replicated } => match replicated {
                Some(o) => o.state_dict(),
                None => dist.sync_state()?,
            },
        };
        let path = path.as_ref();
        super::checkpoint::save_run(path, &self.params, &state)?;
        pub_ev(&self.bus, Event::CheckpointSaved {
            step: self.step as u64,
            path: path.display().to_string(),
        });
        Ok(())
    }

    /// Restore a [`Trainer::save_run_checkpoint`] file into this
    /// trainer (same model/optimizer/worker configuration).
    pub fn load_run_checkpoint(&mut self,
                               path: impl AsRef<std::path::Path>)
        -> Result<()> {
        let (params, state) = super::checkpoint::load_run(path)?;
        if params.len() != self.params.len() {
            bail!("checkpoint has {} params, model has {}", params.len(),
                  self.params.len());
        }
        for (cur, new) in self.params.iter().zip(&params) {
            new.assert_shape(&cur.shape)?;
        }
        self.params = params;
        match &mut self.mode {
            TrainerMode::Host(o) => o.load_state_dict(&state)?,
            TrainerMode::Fused(_) => {
                if !state.is_empty() {
                    bail!("fused trainer cannot import host optimizer \
                           state");
                }
            }
            TrainerMode::Dist { dist, replicated } => match replicated {
                Some(o) => o.load_state_dict(&state)?,
                None => dist.import_state(&state)?,
            },
        }
        Ok(())
    }
}

/// Global-norm clip factor `min(1, clip / ‖ḡ‖)` for a SUMMED gradient
/// whose averaged form is `inv ×` the sum. The norm costs one
/// read-only reduction; the factor itself applies inside the fused
/// update sweep, so clipping adds no gradient-write pass.
fn clip_scale(grads: &[Tensor], inv: f32, clip: f32) -> f32 {
    if clip <= 0.0 {
        return 1.0;
    }
    let sq: f64 = grads.iter().map(|g| g.sq_norm()).sum();
    let norm = sq.sqrt() as f32 * inv;
    if norm > clip { clip / norm } else { 1.0 }
}

fn make_corpus(rt: &ModelRuntime, cfg: &TrainConfig) -> Result<Corpus> {
    // Size the corpus to the run: enough windows for train + val
    // without unintended epoch reuse dominating.
    let need = (cfg.steps.max(64) * cfg.grad_accum.max(1) + 64)
        * rt.mm.batch_size * rt.mm.seq_len / 4;
    let n_tokens = need.clamp(1 << 16, 1 << 23);
    Ok(match cfg.data.as_str() {
        "synthetic" => Corpus::synthetic(&SyntheticSpec {
            vocab: rt.mm.vocab,
            n_tokens,
            coherence: cfg.coherence,
            seed: cfg.seed ^ 0xDA7A,
            ..Default::default()
        }),
        "text" => {
            if rt.mm.vocab < 256 {
                bail!("text corpus needs vocab >= 256, model has {}",
                      rt.mm.vocab);
            }
            Corpus::embedded_text(n_tokens)
        }
        other => bail!("unknown data kind {other:?}"),
    })
}

/// Carve a held-out validation set (4 batches) from the corpus tail.
fn split_batches(corpus: Corpus, bs: usize, seq: usize, seed: u64)
    -> Result<(Batcher, Vec<Batch>)> {
    let n = corpus.len();
    let val_tokens = (4 * bs * seq + 1).min(n / 4);
    let train = Corpus {
        vocab: corpus.vocab,
        tokens: corpus.tokens[..n - val_tokens].to_vec(),
    };
    let val = Corpus {
        vocab: corpus.vocab,
        tokens: corpus.tokens[n - val_tokens..].to_vec(),
    };
    let mut vb = Batcher::new(val, bs, seq, seed ^ 0x7A1);
    let n_val = vb.batches_per_epoch().min(4).max(1);
    let val_batches = (0..n_val).map(|_| vb.next_batch()).collect();
    Ok((Batcher::new(train, bs, seq, seed), val_batches))
}
