//! Checkpointing: a simple self-describing binary tensor container.
//!
//! Layout (little endian): magic `AMCK`, u32 version, u32 tensor count,
//! then per tensor: u32 name-length + name bytes, u32 ndim, u64 dims,
//! f32 data.

use std::fs::{self, File};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::optim::StateDict;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"AMCK";
const VERSION: u32 = 1;

pub fn save_checkpoint(path: impl AsRef<Path>, tensors: &[Tensor])
    -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let name = t.name.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load_checkpoint(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let mut r = BufReader::new(
        File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an AMCK checkpoint");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let mut buf = vec![0u8; n * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        tensors.push(Tensor::new(String::from_utf8(name)?, &shape, data));
    }
    Ok(tensors)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Name prefix that marks optimizer-state tensors inside a run
/// checkpoint (parameters keep their bare names).
const OPT_PREFIX: &str = "opt::";

/// Save a resumable run checkpoint: parameters plus the named
/// optimizer state exported by
/// [`crate::optim::Optimizer::state_dict`] (state keys get an `opt::`
/// prefix inside the container; ZeRO-gathered dicts additionally carry
/// their `rank<r>/` routing prefixes in the key).
pub fn save_run(path: impl AsRef<Path>, params: &[Tensor],
                opt_state: &StateDict) -> Result<()> {
    let mut all: Vec<Tensor> = params.to_vec();
    for t in opt_state.entries() {
        let mut t = t.clone();
        t.name = format!("{OPT_PREFIX}{}", t.name);
        all.push(t);
    }
    save_checkpoint(path, &all)
}

/// Load a [`save_run`] checkpoint back into (params, optimizer state).
pub fn load_run(path: impl AsRef<Path>)
    -> Result<(Vec<Tensor>, StateDict)> {
    let all = load_checkpoint(path)?;
    let mut params = Vec::new();
    let mut state = Vec::new();
    for mut t in all {
        if let Some(stripped) = t.name.strip_prefix(OPT_PREFIX) {
            t.name = stripped.to_string();
            state.push(t);
        } else {
            if !state.is_empty() {
                bail!("malformed run checkpoint: parameter {:?} after \
                       optimizer state", t.name);
            }
            params.push(t);
        }
    }
    Ok((params, StateDict::from_tensors(state)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let tensors = vec![
            Tensor::randn("embed", &[8, 4], 0.5, &mut rng),
            Tensor::randn("final_norm", &[4], 1.0, &mut rng),
            Tensor::zeros("empty-ish", &[1]),
        ];
        let path = std::env::temp_dir().join("amck_test/ckpt.bin");
        save_checkpoint(&path, &tensors).unwrap();
        let back = load_checkpoint(&path).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in tensors.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("amck_test2/garbage.bin");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn run_checkpoint_roundtrips_params_and_state() {
        use crate::optim::{AdamW, Hyper, Optimizer};
        let mut rng = Rng::new(3);
        let mut params = vec![Tensor::randn("w", &[3, 3], 1.0, &mut rng)];
        let grads = vec![Tensor::randn("w", &[3, 3], 1.0, &mut rng)];
        let mut opt = AdamW::new(Hyper::default(), &params);
        opt.step(&mut params, &grads, 1e-2);
        let path = std::env::temp_dir().join("amck_run/ckpt.bin");
        save_run(&path, &params, &opt.state_dict()).unwrap();
        let (p2, s2) = load_run(&path).unwrap();
        assert_eq!(p2, params);
        assert_eq!(s2.len(), 3); // m, v, __step — no silent drop.
        assert!(s2.get("m").is_some() && s2.get("v").is_some());
        let mut opt2 = AdamW::new(Hyper::default(), &p2);
        opt2.load_state_dict(&s2).unwrap();
        // Both instances continue identically.
        let mut pa = params.clone();
        let mut pb = p2;
        opt.step(&mut pa, &grads, 1e-2);
        opt2.step(&mut pb, &grads, 1e-2);
        assert_eq!(pa, pb);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn roundtrip_property() {
        use crate::util::prop::{check, prop_assert};
        check(8, |rng| {
            let n_tensors = 1 + rng.below(4);
            let tensors: Vec<Tensor> = (0..n_tensors)
                .map(|i| {
                    let r = 1 + rng.below(6);
                    let c = 1 + rng.below(6);
                    Tensor::randn(format!("t{i}"), &[r, c], 1.0, rng)
                })
                .collect();
            let path = std::env::temp_dir()
                .join(format!("amck_prop/{}.bin", rng.next_u64()));
            save_checkpoint(&path, &tensors).map_err(|e| e.to_string())?;
            let back =
                load_checkpoint(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();
            prop_assert(back == tensors, "checkpoint round-trip")
        });
    }
}
