//! Evaluation: perplexity and the offline MT-Bench proxy judge.
//!
//! GPT-4-as-judge (paper Table 5) is unavailable offline. The proxy maps
//! (validation perplexity, preference reward) to a 0–10 score that is
//! monotone in the same quality signal the paper's optimizers differ on;
//! DESIGN.md §4 records the substitution. Relative orderings — which is
//! what Table 5 reports — are preserved by any monotone map.

/// Perplexity from mean token cross-entropy (nats).
pub fn perplexity(loss_nats: f64) -> f64 {
    loss_nats.exp()
}

/// MT-Bench-proxy score in [0, 10]: a monotone blend of language-model
/// quality (perplexity, lower better) and preference reward (higher
/// better). `ppl_ref` anchors the scale (score 5 at reference quality,
/// zero reward).
pub fn mt_proxy_score(ppl: f64, reward: f64, ppl_ref: f64) -> f64 {
    let lm_term = 5.0 * (ppl_ref / ppl).min(2.0); // 0..10, 5 at ref
    let rw_term = 2.0 * reward.tanh();            // −2..2
    (lm_term + rw_term).clamp(0.0, 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        let v = 256f64;
        assert!((perplexity(v.ln()) - v).abs() < 1e-9);
    }

    #[test]
    fn proxy_monotone_in_both_signals() {
        let base = mt_proxy_score(20.0, 0.0, 20.0);
        assert!((base - 5.0).abs() < 1e-9);
        assert!(mt_proxy_score(15.0, 0.0, 20.0) > base);
        assert!(mt_proxy_score(25.0, 0.0, 20.0) < base);
        assert!(mt_proxy_score(20.0, 1.0, 20.0) > base);
        assert!(mt_proxy_score(20.0, -1.0, 20.0) < base);
        // Bounded.
        assert!(mt_proxy_score(1.0, 100.0, 20.0) <= 10.0);
        assert!(mt_proxy_score(1e9, -100.0, 20.0) >= 0.0);
    }
}
