//! `serve/` — multi-tenant training service over the shared pool.
//!
//! The coordinator multiplexes many training / SFT / eval jobs from
//! `tenants=N` tenants over `pool=N` workers leased from the dist
//! engine's ring world. Scheduling is round-based gang scheduling:
//! each round the coordinator admits storm arrivals, asks the
//! [`scheduler::Scheduler`] which runnable jobs get the free leases
//! (at most one job per tenant — a tenant's jobs serialize on its
//! single adapter), runs one quantum (`quantum=K` optimizer steps)
//! per leased job concurrently, then collects outcomes. Preemption
//! happens only at quantum (= step) boundaries, and a worker dying
//! mid-quantum surfaces as that JOB failing with a typed
//! [`DistError`] — the service and every other tenant keep going.
//!
//! Everything is deterministic given `storm_seed`: the workload, the
//! schedule, and each tenant's loss trajectory (see [`tenant`] for
//! why trajectories are interleaving-independent). The run emits
//! `Event::Job*` telemetry (feeding `repro top`'s tenants table) and
//! a [`ServeReport`] with throughput, latency percentiles, Jain's
//! fairness index, and the starvation-freedom check that CI enforces.

pub mod job;
pub mod pool;
pub mod scheduler;
pub mod storm;
pub mod tenant;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::dist::DistError;
use crate::telemetry::event::{Event, EventBus, Stamped};
use crate::telemetry::trace::TraceWriter;
use crate::util::json::Json;

pub use job::{Job, JobKind, JobSpec, JobState};
pub use pool::{Lease, WorkerPool};
pub use scheduler::{Candidate, Policy, Scheduler};
pub use tenant::TenantRuntime;

/// Service configuration (the `repro serve key=value` surface).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub tenants: usize,
    pub pool: usize,
    pub sched: String,
    pub storm_seed: u64,
    /// Optimizer steps per lease before the mandatory preemption
    /// point.
    pub quantum: u64,
    pub jobs_per_tenant: usize,
    pub lora_rank: usize,
    pub optimizer: String,
    /// Seed of the shared frozen base table.
    pub base_seed: u64,
    /// Probability a job carries an injected worker fault.
    pub fail_rate: f64,
    /// Mean inter-arrival gap between a tenant's jobs, in rounds.
    pub mean_gap: f64,
    /// JSONL trace output path ("" = no trace).
    pub trace: String,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            tenants: 4,
            pool: 2,
            sched: "fair".to_string(),
            storm_seed: 7,
            quantum: 3,
            jobs_per_tenant: 3,
            lora_rank: 4,
            optimizer: "adam_mini".to_string(),
            base_seed: 0xBA5E,
            fail_rate: 0.0,
            mean_gap: 1.5,
            trace: String::new(),
        }
    }
}

impl ServeConfig {
    /// Parse `key=value` CLI arguments over the defaults.
    pub fn parse_args(args: &[String]) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        for a in args {
            let (k, v) = a.split_once('=').with_context(|| {
                format!("serve arg {a:?}: want key=value")
            })?;
            let c = || format!("serve arg {a:?}");
            match k {
                "tenants" => cfg.tenants = v.parse().with_context(c)?,
                "pool" => cfg.pool = v.parse().with_context(c)?,
                "sched" => {
                    Policy::from_name(v)?;
                    cfg.sched = v.to_string();
                }
                "storm_seed" => {
                    cfg.storm_seed = v.parse().with_context(c)?
                }
                "quantum" => cfg.quantum = v.parse().with_context(c)?,
                "jobs" => {
                    cfg.jobs_per_tenant = v.parse().with_context(c)?
                }
                "rank" => cfg.lora_rank = v.parse().with_context(c)?,
                "optimizer" => cfg.optimizer = v.to_string(),
                "seed" => cfg.base_seed = v.parse().with_context(c)?,
                "fail_rate" => {
                    cfg.fail_rate = v.parse().with_context(c)?
                }
                "mean_gap" => {
                    cfg.mean_gap = v.parse().with_context(c)?
                }
                "trace" => cfg.trace = v.to_string(),
                other => bail!("unknown serve key {other:?}"),
            }
        }
        if cfg.tenants == 0 || cfg.pool == 0 || cfg.quantum == 0 {
            bail!("serve: tenants, pool and quantum must be positive");
        }
        Ok(cfg)
    }
}

/// Terminal record of one job in the report.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: u64,
    pub tenant: String,
    pub kind: String,
    pub state: String,
    pub error: Option<String>,
    pub steps: u64,
    pub latency_rounds: u64,
    pub preemptions: u64,
}

/// Everything a serve run produced (the bench + CI surface).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub sched: String,
    pub tenants: usize,
    pub pool: usize,
    pub jobs: Vec<JobOutcome>,
    pub rounds: u64,
    pub done: usize,
    pub failed: usize,
    /// Longest streak of consecutive rounds any tenant spent
    /// backlogged without service.
    pub max_tenant_wait: u64,
    pub starvation_bound: u64,
    /// Jain's fairness index over per-tenant service rates.
    pub fairness: f64,
    pub p50_latency_rounds: f64,
    pub p95_latency_rounds: f64,
    pub wall_secs: f64,
    pub throughput_jobs_per_s: f64,
    /// Optimizer steps each tenant completed.
    pub tenant_steps: BTreeMap<String, u64>,
    /// Full per-tenant loss trajectories (isolation-test witness).
    pub tenant_losses: BTreeMap<String, Vec<f32>>,
    /// Bytes of tenant state shipped over the pool links.
    pub state_sync_bytes: u64,
}

impl ServeReport {
    pub fn all_terminal(&self) -> bool {
        self.jobs
            .iter()
            .all(|j| j.state == "done" || j.state == "failed")
    }

    /// The CI smoke contract: every job terminal, and under `fair` no
    /// tenant ever waited past the starvation bound.
    pub fn check(&self) -> Result<()> {
        if !self.all_terminal() {
            bail!("serve: non-terminal jobs left in the queue");
        }
        if self.sched == "fair"
            && self.max_tenant_wait > self.starvation_bound
        {
            bail!(
                "serve: starvation under fair: tenant waited {} rounds \
                 (bound {})",
                self.max_tenant_wait, self.starvation_bound
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .jobs
            .iter()
            .map(|j| {
                Json::obj(vec![
                    ("id", Json::num(j.id as f64)),
                    ("tenant", Json::str(&j.tenant)),
                    ("kind", Json::str(&j.kind)),
                    ("state", Json::str(&j.state)),
                    ("error", match &j.error {
                        Some(e) => Json::str(e),
                        None => Json::Null,
                    }),
                    ("steps", Json::num(j.steps as f64)),
                    ("latency_rounds",
                     Json::num(j.latency_rounds as f64)),
                    ("preemptions", Json::num(j.preemptions as f64)),
                ])
            })
            .collect();
        let steps = Json::Obj(
            self.tenant_steps
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("sched", Json::str(&self.sched)),
            ("tenants", Json::num(self.tenants as f64)),
            ("pool", Json::num(self.pool as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("done", Json::num(self.done as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("max_tenant_wait", Json::num(self.max_tenant_wait as f64)),
            ("starvation_bound",
             Json::num(self.starvation_bound as f64)),
            ("fairness", Json::num(self.fairness)),
            ("p50_latency_rounds", Json::num(self.p50_latency_rounds)),
            ("p95_latency_rounds", Json::num(self.p95_latency_rounds)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("throughput_jobs_per_s",
             Json::num(self.throughput_jobs_per_s)),
            ("state_sync_bytes",
             Json::num(self.state_sync_bytes as f64)),
            ("tenant_steps", steps),
            ("jobs", Json::Arr(jobs)),
        ])
    }
}

/// Jain's fairness index: `(Σx)² / (n·Σx²)`, 1.0 = perfectly fair.
pub fn jain_index(xs: &[f64]) -> f64 {
    let active: Vec<f64> =
        xs.iter().copied().filter(|x| x.is_finite()).collect();
    if active.is_empty() {
        return 1.0;
    }
    let s: f64 = active.iter().sum();
    let s2: f64 = active.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        return 1.0;
    }
    s * s / (active.len() as f64 * s2)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One leased quantum's inputs, moved into its worker thread.
struct QuantumWork {
    idx: usize,
    kind: JobKind,
    k: u64,
    fail_at: Option<u64>,
    lease: Lease,
    rt: TenantRuntime,
}

/// Run the seeded storm for this config.
pub fn run(cfg: &ServeConfig) -> Result<ServeReport> {
    run_jobs(cfg, storm::generate(cfg))
}

/// Drive an explicit job list to all-terminal. Public so tests can
/// hand-craft workloads (isolation, preemption, failure injection)
/// against the real scheduler instead of a mock.
pub fn run_jobs(cfg: &ServeConfig, specs: Vec<JobSpec>)
    -> Result<ServeReport> {
    let t0 = Instant::now();
    let policy = Policy::from_name(&cfg.sched)?;
    let mut sched = Scheduler::new(policy);
    let mut pool = WorkerPool::new(cfg.pool);
    let bus = EventBus::new(1 << 16);
    pool.attach_bus(Arc::clone(&bus));
    let base = tenant::shared_base(cfg.base_seed);

    let mut jobs: Vec<Job> = specs
        .into_iter()
        .enumerate()
        .map(|(i, s)| Job::new(s, i as u64))
        .collect();
    let mut admitted = vec![false; jobs.len()];
    let mut runtimes: BTreeMap<String, TenantRuntime> = BTreeMap::new();

    let mut served_quanta: BTreeMap<String, u64> = BTreeMap::new();
    let mut backlogged_rounds: BTreeMap<String, u64> = BTreeMap::new();
    let mut wait: BTreeMap<String, u64> = BTreeMap::new();
    let mut max_wait = 0u64;
    let mut collected: Vec<Stamped> = Vec::new();

    let mut round = 0u64;
    loop {
        // Admit storm arrivals for this round.
        for (i, job) in jobs.iter().enumerate() {
            if !admitted[i] && job.spec.arrival_round <= round {
                admitted[i] = true;
                bus.publish(Event::JobQueued {
                    job: job.spec.id,
                    tenant: job.spec.tenant.clone(),
                    kind: job.spec.kind.name().to_string(),
                    round,
                });
            }
        }
        if jobs.iter().all(|j| j.state.is_terminal()) {
            break;
        }
        // Runnable candidates.
        let candidates: Vec<Candidate> = jobs
            .iter()
            .enumerate()
            .filter(|(i, j)| admitted[*i] && j.state.is_runnable())
            .map(|(_, j)| Candidate {
                job: j.spec.id,
                tenant: j.spec.tenant.clone(),
                prio: j.spec.prio,
                enqueue_seq: j.enqueue_seq,
            })
            .collect();
        let picked = sched.pick(&candidates, pool.free(), round);
        // Service accounting per backlogged tenant.
        let backlogged: std::collections::BTreeSet<&str> =
            candidates.iter().map(|c| c.tenant.as_str()).collect();
        let picked_tenants: std::collections::BTreeSet<String> = picked
            .iter()
            .filter_map(|id| {
                jobs.iter()
                    .find(|j| j.spec.id == *id)
                    .map(|j| j.spec.tenant.clone())
            })
            .collect();
        for t in &backlogged {
            *backlogged_rounds.entry(t.to_string()).or_insert(0) += 1;
            if picked_tenants.contains(*t) {
                *served_quanta.entry(t.to_string()).or_insert(0) += 1;
                wait.insert(t.to_string(), 0);
            } else {
                let w = wait.entry(t.to_string()).or_insert(0);
                *w += 1;
                max_wait = max_wait.max(*w);
            }
        }
        // Lease workers, ship tenant state, launch quanta.
        let mut work: Vec<QuantumWork> = Vec::new();
        for id in &picked {
            let idx =
                jobs.iter().position(|j| j.spec.id == *id).unwrap();
            let lease = pool
                .checkout()
                .expect("scheduler picked more jobs than free leases");
            let spec = jobs[idx].spec.clone();
            let rt = match runtimes.remove(&spec.tenant) {
                Some(rt) => rt,
                None => TenantRuntime::new(
                    &spec.tenant, spec.tenant_seed, cfg.lora_rank,
                    &cfg.optimizer, Arc::clone(&base))?,
            };
            pool.account_ship(lease.id(), rt.state_bytes() as u64);
            let next = match jobs[idx].state {
                JobState::Queued => JobState::Running {
                    lease: lease.id(),
                },
                _ => JobState::Resumed { lease: lease.id() },
            };
            jobs[idx].advance(next)?;
            bus.publish(Event::JobStarted {
                job: spec.id,
                tenant: spec.tenant.clone(),
                lease: lease.id(),
                round,
            });
            let k = (spec.steps - jobs[idx].steps_done)
                .min(cfg.quantum);
            work.push(QuantumWork {
                idx,
                kind: spec.kind,
                k,
                fail_at: spec.fail_at,
                lease,
                rt,
            });
        }
        // One quantum per leased job, concurrently. `run_quantum`
        // returns typed errors instead of panicking, so a fault here
        // fails one job, not the scope.
        type Done = (usize, Lease, TenantRuntime,
                     std::result::Result<Vec<f32>, DistError>);
        let results: Vec<Done> = std::thread::scope(|s| {
            let handles: Vec<_> = work
                .into_iter()
                .map(|w| {
                    s.spawn(move || {
                        let QuantumWork {
                            idx, kind, k, fail_at, lease, mut rt,
                        } = w;
                        let res = rt.run_quantum(kind, k, lease.id(),
                                                 fail_at);
                        (idx, lease, rt, res)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("quantum thread panicked"))
                .collect()
        });
        // Collect outcomes at the step boundary.
        for (idx, lease, rt, res) in results {
            let spec = jobs[idx].spec.clone();
            match res {
                Ok(losses) => {
                    jobs[idx].steps_done += losses.len() as u64;
                    if jobs[idx].steps_done >= spec.steps {
                        let steps = jobs[idx].steps_done;
                        jobs[idx].advance(JobState::Done { steps })?;
                        jobs[idx].finish_round = Some(round);
                        bus.publish(Event::JobFinished {
                            job: spec.id,
                            tenant: spec.tenant.clone(),
                            outcome: "done".to_string(),
                            steps,
                            rounds: jobs[idx]
                                .latency_rounds()
                                .unwrap_or(0),
                        });
                    } else {
                        let at_step = jobs[idx].steps_done;
                        jobs[idx]
                            .advance(JobState::Preempted { at_step })?;
                        bus.publish(Event::JobPreempted {
                            job: spec.id,
                            tenant: spec.tenant.clone(),
                            at_step,
                            round,
                        });
                    }
                }
                Err(err) => {
                    let msg = err.to_string();
                    jobs[idx].advance(JobState::Failed {
                        error: msg.clone(),
                    })?;
                    jobs[idx].finish_round = Some(round);
                    bus.publish(Event::JobFinished {
                        job: spec.id,
                        tenant: spec.tenant.clone(),
                        outcome: "failed".to_string(),
                        steps: jobs[idx].steps_done,
                        rounds: jobs[idx].latency_rounds().unwrap_or(0),
                    });
                }
            }
            pool.account_ship(lease.id(), rt.state_bytes() as u64);
            pool.checkin(lease);
            runtimes.insert(spec.tenant, rt);
        }
        collected.extend(bus.drain());
        round += 1;
        if round > 200_000 {
            bail!("serve: no progress after {round} rounds");
        }
    }
    collected.extend(bus.drain());

    if !cfg.trace.is_empty() {
        let mut w = TraceWriter::create(&cfg.trace)?;
        for st in &collected {
            w.write(st)?;
        }
        w.finish(bus.published(), bus.dropped())?;
    }

    // Report.
    let wall_secs = t0.elapsed().as_secs_f64();
    let outcomes: Vec<JobOutcome> = jobs
        .iter()
        .map(|j| JobOutcome {
            id: j.spec.id,
            tenant: j.spec.tenant.clone(),
            kind: j.spec.kind.name().to_string(),
            state: j.state.name().to_string(),
            error: match &j.state {
                JobState::Failed { error } => Some(error.clone()),
                _ => None,
            },
            steps: j.steps_done,
            latency_rounds: j.latency_rounds().unwrap_or(0),
            preemptions: j.preemptions,
        })
        .collect();
    let done = outcomes.iter().filter(|j| j.state == "done").count();
    let failed =
        outcomes.iter().filter(|j| j.state == "failed").count();
    let rates: Vec<f64> = backlogged_rounds
        .iter()
        .map(|(t, b)| {
            served_quanta.get(t).copied().unwrap_or(0) as f64
                / (*b).max(1) as f64
        })
        .collect();
    let mut lat: Vec<f64> = outcomes
        .iter()
        .map(|j| j.latency_rounds as f64)
        .collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tenant_steps = runtimes
        .iter()
        .map(|(t, rt)| (t.clone(), rt.steps))
        .collect();
    let tenant_losses = runtimes
        .iter()
        .map(|(t, rt)| (t.clone(), rt.losses.clone()))
        .collect();
    Ok(ServeReport {
        sched: cfg.sched.clone(),
        tenants: cfg.tenants,
        pool: cfg.pool,
        rounds: round,
        done,
        failed,
        max_tenant_wait: max_wait,
        starvation_bound: Scheduler::starvation_bound(cfg.tenants,
                                                      cfg.pool),
        fairness: jain_index(&rates),
        p50_latency_rounds: percentile(&lat, 0.50),
        p95_latency_rounds: percentile(&lat, 0.95),
        wall_secs,
        throughput_jobs_per_s: outcomes.len() as f64
            / wall_secs.max(1e-9),
        tenant_steps,
        tenant_losses,
        state_sync_bytes: pool
            .stats()
            .bytes(crate::dist::TrafficClass::StateSync),
        jobs: outcomes,
    })
}

/// Print the operator-facing report for `repro serve`.
pub fn print_report(r: &ServeReport) {
    println!("== serve: {} tenants over {} workers (sched={}) ==",
             r.tenants, r.pool, r.sched);
    let hdr = ["job", "tenant", "kind", "state", "steps", "latency",
               "preempts"];
    let rows: Vec<Vec<String>> = r
        .jobs
        .iter()
        .map(|j| {
            vec![
                format!("{}", j.id),
                j.tenant.clone(),
                j.kind.clone(),
                match &j.error {
                    Some(e) => format!("{} ({e})", j.state),
                    None => j.state.clone(),
                },
                format!("{}", j.steps),
                format!("{}", j.latency_rounds),
                format!("{}", j.preemptions),
            ]
        })
        .collect();
    print!("{}", crate::util::csv::ascii_table(&hdr, &rows));
    println!(
        "jobs: {} done, {} failed over {} rounds in {:.2}s \
         ({:.1} jobs/s)",
        r.done, r.failed, r.rounds, r.wall_secs,
        r.throughput_jobs_per_s
    );
    println!(
        "latency p50 {:.0} / p95 {:.0} rounds; fairness {:.3}; \
         max wait {} (bound {}); state shipped {}",
        r.p50_latency_rounds, r.p95_latency_rounds, r.fairness,
        r.max_tenant_wait, r.starvation_bound,
        crate::telemetry::top::fmt_bytes(r.state_sync_bytes)
    );
}

/// Shared-base memory model cross-check for `repro report`
/// (closed-form `cluster::shared_base_bytes` vs bytes measured from
/// live tenant runtimes).
pub fn memory_report() -> Result<()> {
    use crate::cluster::{full_replica_bytes, shared_base_bytes,
                         ADAMW_PROFILE, ADAM_MINI_PROFILE};
    use crate::telemetry::top::fmt_bytes;
    let tenants = 4;
    let rank = 4;
    let base = tenant::shared_base(0xBA5E);
    let base_params = base.numel();
    println!();
    println!(
        "== serve memory: {tenants} tenants, shared base vs full \
         replicas =="
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (opt, profile) in [("adam_mini", &ADAM_MINI_PROFILE),
                           ("adamw", &ADAMW_PROFILE)] {
        let mut measured = (base_params * 4) as f64;
        let mut adapter_params = 0usize;
        for t in 0..tenants {
            let rt = TenantRuntime::new(
                &format!("t{t}"), t as u64 + 1, rank, opt,
                Arc::clone(&base))?;
            adapter_params =
                rt.params.iter().map(|p| p.numel()).sum();
            measured += rt.state_bytes() as f64;
        }
        let modeled = shared_base_bytes(base_params as f64,
                                        adapter_params as f64,
                                        profile, tenants);
        let replicas = full_replica_bytes(base_params as f64, profile,
                                          tenants);
        let delta = (measured - modeled).abs() / modeled.max(1.0);
        rows.push(vec![
            profile.name.to_string(),
            fmt_bytes(measured as u64),
            fmt_bytes(modeled as u64),
            format!("{:.1}%", delta * 100.0),
            fmt_bytes(replicas as u64),
            format!("{:.1}x", replicas / measured),
            if delta < 0.10 { "OK".into() } else { "FAIL".into() },
        ]);
    }
    let hdr = ["optimizer", "measured", "modeled", "delta",
               "n replicas", "savings", "check"];
    print!("{}", crate::util::csv::ascii_table(&hdr, &rows));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_args_overrides_defaults() {
        let args: Vec<String> =
            ["tenants=6", "pool=3", "sched=fifo", "storm_seed=9",
             "quantum=2", "rank=8", "fail_rate=0.5"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = ServeConfig::parse_args(&args).unwrap();
        assert_eq!(cfg.tenants, 6);
        assert_eq!(cfg.pool, 3);
        assert_eq!(cfg.sched, "fifo");
        assert_eq!(cfg.storm_seed, 9);
        assert_eq!(cfg.quantum, 2);
        assert_eq!(cfg.lora_rank, 8);
        assert!((cfg.fail_rate - 0.5).abs() < 1e-12);
    }

    #[test]
    fn parse_args_rejects_junk() {
        let bad = |s: &str| {
            ServeConfig::parse_args(&[s.to_string()]).is_err()
        };
        assert!(bad("tenants"));
        assert!(bad("tenants=x"));
        assert!(bad("sched=lifo"));
        assert!(bad("warp=9"));
        assert!(bad("pool=0"));
    }

    #[test]
    fn jain_index_behaves() {
        assert!((jain_index(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        // One tenant hogging everything over n tenants → 1/n.
        let j = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12);
        assert_eq!(jain_index(&[]), 1.0);
    }

    #[test]
    fn percentile_picks_sorted_positions() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.95), 5.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
