//! Per-tenant runtime: LoRA-style adapter over one shared frozen base.
//!
//! Every tenant owns a tiny low-rank adapter (`lora_a` ∈ [VOCAB×r],
//! `lora_b` ∈ [r×VOCAB]) applied additively to a single frozen bigram
//! base shared by the whole service — this is the memory argument for
//! multi-tenancy: N tenants cost `base + N·adapter·(1 + opt_state)`
//! bytes instead of N full replicas (see `cluster::shared_base_bytes`,
//! which `repro report` cross-checks against these structs). With
//! Adam-mini the per-adapter optimizer state is halved again, so the
//! same pool packs ~2× the tenants of AdamW.
//!
//! The runtime is deliberately self-contained and deterministic:
//! adapter init and the data stream derive only from the tenant seed,
//! so a tenant's loss trajectory is a pure function of (seed, number
//! of batches consumed) — independent of how its quanta interleave
//! with other tenants. That is the isolation property the serve tests
//! assert bit-exactly, and it is also what makes preempt → checkpoint
//! → resume equivalence testable: resume replays the batch cursor and
//! reloads optimizer state through `StateDict` under the
//! `tenant/<id>/` key prefix.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::bigram::VOCAB;
use crate::data::batcher::{Batch, Batcher};
use crate::data::corpus::{Corpus, SyntheticSpec};
use crate::dist::shard::{build_shard_optimizer, SendOptimizer};
use crate::dist::DistError;
use crate::optim::{Hyper, ModelMeta, ReduceOp};
use crate::partition::Strategy;
use crate::tensor::Tensor;
use crate::util::prng::Rng;

use super::job::JobKind;

/// Checkpoint key prefix for one tenant: `tenant/<id>/...`.
pub fn key_prefix(id: &str) -> String {
    format!("tenant/{id}/")
}

/// Build the frozen base table shared by every tenant (same init
/// idiom as the coordinator's bigram model).
pub fn shared_base(seed: u64) -> Arc<Tensor> {
    let mut rng = Rng::new(seed);
    Arc::new(Tensor::randn("base", &[VOCAB, VOCAB], 0.1, &mut rng))
}

/// One tenant's live training state: adapter params, optimizer,
/// deterministic batch stream, and counters.
pub struct TenantRuntime {
    pub id: String,
    pub seed: u64,
    pub lora_rank: usize,
    base: Arc<Tensor>,
    /// `[lora_a [VOCAB,r], lora_b [r,VOCAB]]`.
    pub params: Vec<Tensor>,
    opt: SendOptimizer,
    optimizer_name: String,
    batcher: Batcher,
    /// Batches consumed (every kind — this is the resume cursor).
    pub batches: u64,
    /// Optimizer steps taken (param-updating kinds only).
    pub steps: u64,
    /// Loss of every batch ever run, in order (isolation witness).
    pub losses: Vec<f32>,
}

fn adapter_params(seed: u64, rank: usize) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    vec![
        Tensor::randn("lora_a", &[VOCAB, rank], 0.02, &mut rng),
        Tensor::zeros("lora_b", &[rank, VOCAB]),
    ]
}

fn adapter_meta() -> ModelMeta {
    ModelMeta { n_heads: 1, stacked: vec![] }
}

impl TenantRuntime {
    pub fn new(id: &str, seed: u64, lora_rank: usize, optimizer: &str,
               base: Arc<Tensor>) -> Result<TenantRuntime> {
        let params = adapter_params(seed, lora_rank);
        let spec = adapter_meta().spec_for(&params, Strategy::Hessian)?;
        let opt = build_shard_optimizer(optimizer, Hyper::default(),
                                        &params, Some(spec),
                                        ReduceOp::Mean)?;
        let corpus = Corpus::synthetic(&SyntheticSpec {
            vocab: VOCAB,
            n_tokens: 8_192,
            seed: seed ^ 0xDA7A,
            ..Default::default()
        });
        let batcher = Batcher::new(corpus, 4, 16, seed);
        Ok(TenantRuntime {
            id: id.to_string(),
            seed,
            lora_rank,
            base,
            params,
            opt,
            optimizer_name: optimizer.to_string(),
            batcher,
            batches: 0,
            steps: 0,
            losses: Vec::new(),
        })
    }

    /// Adapted logits loss + analytic adapter gradients for one batch:
    /// `logits[tok, j] = base[tok, j] + Σ_k A[tok, k]·B[k, j]` with
    /// softmax cross-entropy, mirroring the coordinator bigram path.
    fn loss_grad(&self, batch: &Batch) -> (f32, Vec<Tensor>) {
        let v = VOCAB;
        let r = self.lora_rank;
        let a = &self.params[0].data;
        let b = &self.params[1].data;
        let base = &self.base.data;
        let mut da = vec![0f32; v * r];
        let mut db = vec![0f32; r * v];
        let inv = 1.0 / batch.tokens.len() as f32;
        let mut total = 0f64;
        let mut row = vec![0f32; v];
        let mut exps = vec![0f32; v];
        for (&tok, &tgt) in batch.tokens.iter().zip(&batch.targets) {
            let (tok, tgt) = (tok as usize, tgt as usize);
            for j in 0..v {
                let mut acc = base[tok * v + j];
                for (k, ak) in a[tok * r..(tok + 1) * r].iter()
                    .enumerate() {
                    acc += ak * b[k * v + j];
                }
                row[j] = acc;
            }
            let mx =
                row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for j in 0..v {
                exps[j] = (row[j] - mx).exp();
                z += exps[j];
            }
            total += (z.ln() + mx - row[tgt]) as f64;
            for j in 0..v {
                let mut d = exps[j] / z * inv;
                if j == tgt {
                    d -= inv;
                }
                for k in 0..r {
                    da[tok * r + k] += d * b[k * v + j];
                    db[k * v + j] += a[tok * r + k] * d;
                }
            }
        }
        let loss = (total * inv as f64) as f32;
        (loss, vec![
            Tensor::new("lora_a", &[v, r], da),
            Tensor::new("lora_b", &[r, v], db),
        ])
    }

    /// Run up to `k` steps of `kind` on the leased worker. Stops early
    /// (with a typed per-job error, never a panic) when fault
    /// injection says the worker dies at this tenant-batch index.
    /// Returns the losses of the batches that ran.
    pub fn run_quantum(&mut self, kind: JobKind, k: u64, lease: usize,
                       fail_at: Option<u64>)
        -> std::result::Result<Vec<f32>, DistError> {
        let mut out = Vec::new();
        for _ in 0..k {
            if fail_at == Some(self.batches) {
                return Err(DistError::WorkerPanicked { rank: lease });
            }
            let batch = self.batcher.next_batch();
            let (loss, grads) = self.loss_grad(&batch);
            if kind.updates_params() {
                self.opt.step(&mut self.params, &grads, kind.lr());
                self.steps += 1;
            }
            self.batches += 1;
            self.losses.push(loss);
            out.push(loss);
        }
        Ok(out)
    }

    /// Serialize adapter + optimizer state + cursor under the
    /// `tenant/<id>/` prefix: `…/param/<name>`, `…/opt::<key>`, and a
    /// 2-elem `…/meta` cursor tensor `[batches, steps]`.
    pub fn checkpoint(&self) -> crate::optim::StateDict {
        let pre = key_prefix(&self.id);
        let mut sd = crate::optim::StateDict::new();
        for t in &self.params {
            sd.insert(format!("{pre}param/{}", t.name), &t.shape,
                      t.data.clone());
        }
        for t in self.opt.state_dict().into_tensors() {
            sd.insert(format!("{pre}opt::{}", t.name), &t.shape,
                      t.data.clone());
        }
        sd.insert(format!("{pre}meta"), &[2],
                  vec![self.batches as f32, self.steps as f32]);
        sd
    }

    /// Rebuild a runtime from a checkpoint: fresh init from the same
    /// seed, overwrite adapter + optimizer state, replay the batch
    /// cursor. The result is step-for-step identical to the runtime
    /// that produced the checkpoint (asserted by tier-1 tests).
    pub fn resume(id: &str, seed: u64, lora_rank: usize,
                  optimizer: &str, base: Arc<Tensor>,
                  sd: &crate::optim::StateDict)
        -> Result<TenantRuntime> {
        let mut rt =
            TenantRuntime::new(id, seed, lora_rank, optimizer, base)?;
        let sub = sd.sub_dict(&key_prefix(id));
        if sub.is_empty() {
            bail!("checkpoint has no state for tenant {id:?}");
        }
        for p in &mut rt.params {
            let src = sub.require(&format!("param/{}", p.name))?;
            src.assert_shape(&p.shape)?;
            p.data.copy_from_slice(&src.data);
        }
        rt.opt.load_state_dict(&sub.sub_dict("opt::"))?;
        let meta = sub.require("meta")?;
        if meta.data.len() != 2 {
            bail!("tenant {id:?}: malformed meta cursor");
        }
        rt.batches = meta.data[0] as u64;
        rt.steps = meta.data[1] as u64;
        for _ in 0..rt.batches {
            rt.batcher.next_batch();
        }
        Ok(rt)
    }

    /// Live bytes this tenant adds on top of the shared base: adapter
    /// params + optimizer state (measured, for the cluster-model
    /// cross-check).
    pub fn state_bytes(&self) -> usize {
        let p: usize =
            self.params.iter().map(|t| t.numel() * 4).sum::<usize>();
        p + self.opt.state_bytes()
    }

    pub fn optimizer_name(&self) -> &str {
        &self.optimizer_name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(id: &str, seed: u64) -> TenantRuntime {
        TenantRuntime::new(id, seed, 4, "adam_mini", shared_base(0xBA5E))
            .unwrap()
    }

    #[test]
    fn quantum_updates_adapter_and_counters() {
        let mut t = rt("a", 11);
        let before = t.params[0].data.clone();
        let losses = t.run_quantum(JobKind::Train, 3, 0, None).unwrap();
        assert_eq!(losses.len(), 3);
        assert_eq!(t.batches, 3);
        assert_eq!(t.steps, 3);
        assert_ne!(t.params[0].data, before, "train must move lora_a");
    }

    #[test]
    fn eval_never_touches_params() {
        let mut t = rt("a", 11);
        let before = (t.params[0].data.clone(), t.params[1].data.clone());
        t.run_quantum(JobKind::Eval, 4, 0, None).unwrap();
        assert_eq!(t.params[0].data, before.0);
        assert_eq!(t.params[1].data, before.1);
        assert_eq!(t.steps, 0);
        assert_eq!(t.batches, 4);
    }

    #[test]
    fn fault_injection_is_a_typed_error() {
        let mut t = rt("a", 11);
        let err = t.run_quantum(JobKind::Train, 5, 2, Some(3))
            .unwrap_err();
        assert!(matches!(err, DistError::WorkerPanicked { rank: 2 }));
        // Exactly the steps before the fault ran.
        assert_eq!(t.batches, 3);
    }

    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let base = shared_base(0xBA5E);
        let mut a = TenantRuntime::new("t0", 7, 4, "adam_mini",
                                       Arc::clone(&base)).unwrap();
        a.run_quantum(JobKind::Train, 5, 0, None).unwrap();
        let sd = a.checkpoint();
        assert!(sd.keys().all(|k| k.starts_with("tenant/t0/")));
        let mut b = TenantRuntime::resume("t0", 7, 4, "adam_mini",
                                          Arc::clone(&base), &sd)
            .unwrap();
        let la = a.run_quantum(JobKind::Train, 4, 0, None).unwrap();
        let lb = b.run_quantum(JobKind::Train, 4, 0, None).unwrap();
        assert_eq!(la.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   lb.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        assert_eq!(a.params[0].data, b.params[0].data);
        assert_eq!(a.params[1].data, b.params[1].data);
    }

    #[test]
    fn different_seeds_different_trajectories() {
        let mut a = rt("a", 1);
        let mut b = rt("b", 2);
        let la = a.run_quantum(JobKind::Train, 3, 0, None).unwrap();
        let lb = b.run_quantum(JobKind::Train, 3, 0, None).unwrap();
        assert_ne!(la, lb);
    }
}
