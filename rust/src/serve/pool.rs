//! Shared worker pool: leases over the dist engine's ring world.
//!
//! The pool owns `pool=N` ring nodes (the same `RingNode` + link
//! model the dist engine trains over) and hands them out as [`Lease`]s
//! for one quantum at a time. Checking a tenant in/out ships its
//! adapter + optimizer state across the link, which is accounted on
//! the shared [`CommStats`] ledger under `StateSync` — so `repro top`
//! and the traffic report see serve traffic through exactly the same
//! pipe as training traffic.

use std::sync::Arc;

use crate::dist::comm::{ring_world, CommStats, LinkModel, RingNode};
use crate::dist::TrafficClass;
use crate::telemetry::event::EventBus;

/// Exclusive use of one pooled worker for one quantum. Returning the
/// lease (via [`WorkerPool::checkin`]) is the only way the node goes
/// back — preemption is just an early checkin at a step boundary.
pub struct Lease {
    id: usize,
    node: RingNode,
}

impl Lease {
    /// Pool slot index (doubles as the worker rank in events).
    pub fn id(&self) -> usize {
        self.id
    }

    /// The leased ring node (rank/world inspection, link access).
    pub fn node(&self) -> &RingNode {
        &self.node
    }
}

/// Fixed-size pool of ring workers with lease accounting.
pub struct WorkerPool {
    slots: Vec<Option<RingNode>>,
    stats: Arc<CommStats>,
}

impl WorkerPool {
    pub fn new(size: usize) -> WorkerPool {
        let (nodes, stats) = ring_world(size.max(1),
                                        LinkModel::default());
        WorkerPool {
            slots: nodes.into_iter().map(Some).collect(),
            stats,
        }
    }

    /// Mirror serve traffic onto a telemetry bus (feeds `repro top`).
    pub fn attach_bus(&self, bus: Arc<EventBus>) {
        self.stats.attach_bus(bus);
    }

    pub fn size(&self) -> usize {
        self.slots.len()
    }

    /// Leases currently available.
    pub fn free(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Lease the lowest free slot, if any.
    pub fn checkout(&mut self) -> Option<Lease> {
        let id = self.slots.iter().position(|s| s.is_some())?;
        let node = self.slots[id].take().unwrap();
        Some(Lease { id, node })
    }

    /// Return a lease to its slot.
    pub fn checkin(&mut self, lease: Lease) {
        debug_assert!(self.slots[lease.id].is_none(),
                      "double checkin of lease {}", lease.id);
        self.slots[lease.id] = Some(lease.node);
    }

    /// Account shipping `bytes` of tenant state to/from slot `id`
    /// (adapter + optimizer state at checkout/checkin). Flows into
    /// the shared comm ledger as `StateSync` traffic and, when a bus
    /// is attached, into `Event::Message` for the dashboard.
    pub fn account_ship(&self, id: usize, bytes: u64) {
        self.stats.record_from(id, TrafficClass::StateSync, bytes);
    }

    /// The shared comm ledger (serve + dist traffic on one ledger).
    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_exhausts_then_checkin_replenishes() {
        let mut p = WorkerPool::new(2);
        assert_eq!(p.free(), 2);
        let a = p.checkout().unwrap();
        let b = p.checkout().unwrap();
        assert_eq!((a.id(), b.id()), (0, 1));
        assert!(p.checkout().is_none());
        assert_eq!(p.free(), 0);
        p.checkin(a);
        assert_eq!(p.free(), 1);
        // The freed slot is re-issued with the same identity.
        let a2 = p.checkout().unwrap();
        assert_eq!(a2.id(), 0);
        assert_eq!(a2.node().rank, 0);
        p.checkin(a2);
        p.checkin(b);
        assert_eq!(p.free(), 2);
    }

    #[test]
    fn ship_accounting_lands_on_state_sync() {
        let p = WorkerPool::new(1);
        p.account_ship(0, 4096);
        assert_eq!(p.stats().bytes(TrafficClass::StateSync), 4096);
    }
}
