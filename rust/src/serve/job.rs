//! Typed job specs and the job state machine.
//!
//! A job is one unit of tenant work (a training run, an SFT pass, or
//! an eval sweep) scheduled in quanta over the shared worker pool.
//! States move `Queued → Running → {Done, Failed, Preempted}` and
//! `Preempted → Resumed → …`; [`Job::advance`] rejects every other
//! edge, so a scheduler bug surfaces as a typed error instead of a
//! silently corrupted queue. Failures carry the [`DistError`]
//! taxonomy's message — a worker dying takes down the JOB, never the
//! process.

use anyhow::{bail, Result};

/// What kind of work a job runs. The kind picks the per-step learning
/// rate (and whether parameters update at all); all kinds share the
/// tenant's adapter and batch stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Pre-training-style pass: full learning rate.
    Train,
    /// Supervised fine-tune: reduced learning rate.
    Sft,
    /// Eval sweep: losses only, no parameter updates.
    Eval,
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Train => "train",
            JobKind::Sft => "sft",
            JobKind::Eval => "eval",
        }
    }

    pub fn from_name(s: &str) -> Result<JobKind> {
        Ok(match s {
            "train" => JobKind::Train,
            "sft" => JobKind::Sft,
            "eval" => JobKind::Eval,
            other => bail!("unknown job kind {other:?}"),
        })
    }

    /// Whether steps of this kind update the adapter.
    pub fn updates_params(&self) -> bool {
        !matches!(self, JobKind::Eval)
    }

    /// Per-step learning rate for this kind (constant schedule; the
    /// service quantum is too short for a warmup to matter).
    pub fn lr(&self) -> f32 {
        match self {
            JobKind::Train => 3e-2,
            JobKind::Sft => 1e-2,
            JobKind::Eval => 0.0,
        }
    }
}

/// Immutable description of one job, produced by the request storm
/// (or a test) before the job is admitted.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: u64,
    /// Owning tenant (adapter + optimizer state + batch stream).
    pub tenant: String,
    /// Seed for the tenant's adapter init and data stream — shared by
    /// every job of the same tenant.
    pub tenant_seed: u64,
    pub kind: JobKind,
    /// Higher runs earlier under `sched=priority`.
    pub prio: u8,
    /// Total optimizer steps (or eval batches) this job demands.
    pub steps: u64,
    /// Scheduler round at which the job arrives (Poisson storm).
    pub arrival_round: u64,
    /// Fault injection: the worker "panics" when the tenant reaches
    /// this absolute step — surfaces as a per-job `DistError`.
    pub fail_at: Option<u64>,
}

/// The job state machine.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    /// Admitted, waiting for its first lease.
    Queued,
    /// Holding a lease, first quantum.
    Running { lease: usize },
    /// Lease returned at a step boundary; waiting to be rescheduled.
    Preempted { at_step: u64 },
    /// Holding a lease again after a preemption.
    Resumed { lease: usize },
    /// Terminal: every demanded step ran.
    Done { steps: u64 },
    /// Terminal: a quantum died with a `DistError` (message kept).
    Failed { error: String },
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running { .. } => "running",
            JobState::Preempted { .. } => "preempted",
            JobState::Resumed { .. } => "resumed",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done { .. } | JobState::Failed { .. })
    }

    /// Holding a lease right now.
    pub fn is_active(&self) -> bool {
        matches!(self,
                 JobState::Running { .. } | JobState::Resumed { .. })
    }

    /// Schedulable: waiting for a lease.
    pub fn is_runnable(&self) -> bool {
        matches!(self, JobState::Queued | JobState::Preempted { .. })
    }

    fn legal(&self, next: &JobState) -> bool {
        use JobState::*;
        match (self, next) {
            (Queued, Running { .. }) => true,
            (Running { .. } | Resumed { .. },
             Done { .. } | Failed { .. } | Preempted { .. }) => true,
            (Preempted { .. }, Resumed { .. }) => true,
            _ => false,
        }
    }
}

/// One job's full scheduler-side record: spec + state machine +
/// latency bookkeeping.
#[derive(Debug, Clone)]
pub struct Job {
    pub spec: JobSpec,
    pub state: JobState,
    /// Optimizer steps (or eval batches) completed so far.
    pub steps_done: u64,
    /// Admission order (FIFO tie-break inside a tenant).
    pub enqueue_seq: u64,
    /// Round the job finished, if terminal.
    pub finish_round: Option<u64>,
    /// Times this job was preempted.
    pub preemptions: u64,
}

impl Job {
    pub fn new(spec: JobSpec, enqueue_seq: u64) -> Job {
        Job {
            spec,
            state: JobState::Queued,
            steps_done: 0,
            enqueue_seq,
            finish_round: None,
            preemptions: 0,
        }
    }

    /// Advance the state machine, rejecting illegal edges.
    pub fn advance(&mut self, next: JobState) -> Result<()> {
        if !self.state.legal(&next) {
            bail!("job {}: illegal transition {} -> {}",
                  self.spec.id, self.state.name(), next.name());
        }
        if let JobState::Preempted { .. } = next {
            self.preemptions += 1;
        }
        self.state = next;
        Ok(())
    }

    /// Completion latency in scheduler rounds (arrival inclusive).
    pub fn latency_rounds(&self) -> Option<u64> {
        self.finish_round
            .map(|f| f + 1 - self.spec.arrival_round.min(f + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: u64) -> JobSpec {
        JobSpec {
            id,
            tenant: "t0".into(),
            tenant_seed: 1,
            kind: JobKind::Train,
            prio: 0,
            steps: 8,
            arrival_round: 0,
            fail_at: None,
        }
    }

    #[test]
    fn happy_path_transitions() {
        let mut j = Job::new(spec(1), 0);
        j.advance(JobState::Running { lease: 0 }).unwrap();
        j.advance(JobState::Preempted { at_step: 3 }).unwrap();
        assert_eq!(j.preemptions, 1);
        assert!(j.state.is_runnable());
        j.advance(JobState::Resumed { lease: 1 }).unwrap();
        assert!(j.state.is_active());
        j.advance(JobState::Done { steps: 8 }).unwrap();
        assert!(j.state.is_terminal());
    }

    #[test]
    fn failure_is_terminal_from_either_active_state() {
        let mut j = Job::new(spec(2), 0);
        j.advance(JobState::Running { lease: 0 }).unwrap();
        j.advance(JobState::Failed { error: "rank 0: worker \
                                             panicked".into() })
            .unwrap();
        assert!(j.state.is_terminal());
        // Terminal is a sink.
        assert!(j.advance(JobState::Running { lease: 0 }).is_err());
    }

    #[test]
    fn illegal_edges_are_rejected() {
        let mut j = Job::new(spec(3), 0);
        // Queued cannot finish or resume without running first.
        assert!(j.clone().advance(JobState::Done { steps: 0 }).is_err());
        assert!(j
            .clone()
            .advance(JobState::Resumed { lease: 0 })
            .is_err());
        j.advance(JobState::Running { lease: 0 }).unwrap();
        // Running cannot re-run or go back to queued.
        assert!(j
            .clone()
            .advance(JobState::Running { lease: 1 })
            .is_err());
        assert!(j.clone().advance(JobState::Queued).is_err());
    }

    #[test]
    fn kind_roundtrip_and_lr() {
        for k in [JobKind::Train, JobKind::Sft, JobKind::Eval] {
            assert_eq!(JobKind::from_name(k.name()).unwrap(), k);
        }
        assert!(JobKind::Eval.lr() == 0.0);
        assert!(!JobKind::Eval.updates_params());
        assert!(JobKind::Train.updates_params());
    }

    #[test]
    fn latency_counts_from_arrival() {
        let mut j = Job::new(spec(4), 0);
        j.spec.arrival_round = 2;
        j.finish_round = Some(5);
        assert_eq!(j.latency_rounds(), Some(4));
    }
}
