//! Quantum scheduler: who gets the free leases this round.
//!
//! Three policies share one interface. `fifo` is strict admission
//! order, `priority` is highest-priority-first (starvable by design —
//! the smoke test demonstrates why `fair` is the default), and `fair`
//! is deficit round-robin across tenants: every round each backlogged
//! tenant banks one credit, the richest tenants run, and running
//! spends a credit. Because credits grow while a tenant waits and are
//! spent when it runs, a backlogged tenant's wait is bounded by
//! ⌈tenants / pool⌉ + 2 rounds — the starvation-freedom invariant the
//! serve report checks after every run.
//!
//! All policies schedule at most ONE job per tenant per round: a
//! tenant's jobs serialize on its single adapter, which is what makes
//! tenant trajectories independent of cross-tenant interleaving
//! (the bit-exact isolation property).

use std::cmp::Reverse;
use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Scheduling policy, parsed from the `sched=` CLI key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fair,
    Fifo,
    Priority,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fair => "fair",
            Policy::Fifo => "fifo",
            Policy::Priority => "priority",
        }
    }

    pub fn from_name(s: &str) -> Result<Policy> {
        Ok(match s {
            "fair" => Policy::Fair,
            "fifo" => Policy::Fifo,
            "priority" => Policy::Priority,
            other => bail!(
                "unknown sched {other:?} (want fair|fifo|priority)"),
        })
    }
}

/// One runnable job as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub job: u64,
    pub tenant: String,
    pub prio: u8,
    pub enqueue_seq: u64,
}

/// Stateful scheduler (the deficit ledger persists across rounds).
pub struct Scheduler {
    policy: Policy,
    /// Fair-share credits per tenant. Banked while backlogged, spent
    /// when served, reset when the tenant has no runnable work.
    deficit: BTreeMap<String, i64>,
    /// Round a tenant was last served (fair tie-break: longest unserved
    /// first).
    last_served: BTreeMap<String, u64>,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            deficit: BTreeMap::new(),
            last_served: BTreeMap::new(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Every scheduler guarantees a tenant with runnable work waits at
    /// most this many consecutive rounds under `fair` (checked by the
    /// serve report; meaningless for fifo/priority, which starve).
    pub fn starvation_bound(tenants: usize, pool: usize) -> u64 {
        (tenants as u64).div_ceil(pool.max(1) as u64) + 2
    }

    /// Choose up to `free` jobs to lease this round. At most one job
    /// per tenant; within a tenant the oldest job wins.
    pub fn pick(&mut self, candidates: &[Candidate], free: usize,
                round: u64) -> Vec<u64> {
        if free == 0 || candidates.is_empty() {
            // Still bank credits so waiting tenants gain ground.
            self.bank(candidates);
            return Vec::new();
        }
        // One representative per tenant: lowest enqueue_seq.
        let mut per_tenant: BTreeMap<&str, &Candidate> = BTreeMap::new();
        for c in candidates {
            per_tenant
                .entry(c.tenant.as_str())
                .and_modify(|cur| {
                    if c.enqueue_seq < cur.enqueue_seq {
                        *cur = c;
                    }
                })
                .or_insert(c);
        }
        let mut reps: Vec<&Candidate> =
            per_tenant.into_values().collect();
        self.bank(candidates);
        match self.policy {
            Policy::Fifo => {
                reps.sort_by_key(|c| c.enqueue_seq);
            }
            Policy::Priority => {
                reps.sort_by_key(|c| (Reverse(c.prio), c.enqueue_seq));
            }
            Policy::Fair => {
                reps.sort_by_key(|c| {
                    let d =
                        self.deficit.get(&c.tenant).copied().unwrap_or(0);
                    let last = self
                        .last_served
                        .get(&c.tenant)
                        .copied()
                        .unwrap_or(0);
                    (Reverse(d), last, c.enqueue_seq)
                });
            }
        }
        let chosen: Vec<&Candidate> =
            reps.into_iter().take(free).collect();
        for c in &chosen {
            *self.deficit.entry(c.tenant.clone()).or_insert(0) -= 1;
            self.last_served.insert(c.tenant.clone(), round);
        }
        chosen.iter().map(|c| c.job).collect()
    }

    /// Bank one credit per backlogged tenant; reset tenants with no
    /// runnable work so an idle tenant cannot hoard credit and later
    /// monopolize the pool.
    fn bank(&mut self, candidates: &[Candidate]) {
        let backlogged: std::collections::BTreeSet<&str> =
            candidates.iter().map(|c| c.tenant.as_str()).collect();
        self.deficit.retain(|t, _| backlogged.contains(t.as_str()));
        for t in backlogged {
            *self.deficit.entry(t.to_string()).or_insert(0) += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(job: u64, tenant: &str, prio: u8, seq: u64) -> Candidate {
        Candidate { job, tenant: tenant.into(), prio, enqueue_seq: seq }
    }

    #[test]
    fn one_job_per_tenant_per_round() {
        let mut s = Scheduler::new(Policy::Fifo);
        let cs = vec![
            cand(1, "a", 0, 0),
            cand(2, "a", 0, 1),
            cand(3, "b", 0, 2),
        ];
        let picked = s.pick(&cs, 4, 0);
        // Plenty of leases, but tenant `a` serializes: job 2 waits.
        assert_eq!(picked, vec![1, 3]);
    }

    #[test]
    fn fifo_is_admission_order() {
        let mut s = Scheduler::new(Policy::Fifo);
        let cs = vec![cand(1, "a", 9, 5), cand(2, "b", 0, 1)];
        assert_eq!(s.pick(&cs, 1, 0), vec![2]);
    }

    #[test]
    fn priority_runs_high_prio_first_and_starves() {
        let mut s = Scheduler::new(Policy::Priority);
        let cs = vec![cand(1, "lo", 0, 0), cand(2, "hi", 3, 9)];
        // High priority wins every round while it has work — the low
        // tenant starves for as long as that holds.
        for round in 0..5 {
            assert_eq!(s.pick(&cs, 1, round), vec![2]);
        }
    }

    #[test]
    fn fair_round_robins_under_contention() {
        let mut s = Scheduler::new(Policy::Fair);
        let cs = vec![
            cand(1, "a", 0, 0),
            cand(2, "b", 0, 1),
            cand(3, "c", 0, 2),
        ];
        // Pool of one lease, three backlogged tenants: every tenant is
        // served within the starvation bound.
        let mut served: BTreeMap<u64, u64> = BTreeMap::new();
        for round in 0..6 {
            for j in s.pick(&cs, 1, round) {
                *served.entry(j).or_insert(0) += 1;
            }
        }
        assert_eq!(served.len(), 3, "all tenants served: {served:?}");
        let counts: Vec<u64> = served.values().copied().collect();
        assert!(counts.iter().all(|&c| c == 2),
                "equal service under fair: {served:?}");
    }

    #[test]
    fn fair_wait_stays_under_bound() {
        let mut s = Scheduler::new(Policy::Fair);
        let tenants = 5;
        let pool = 2;
        let bound = Scheduler::starvation_bound(tenants, pool);
        let cs: Vec<Candidate> = (0..tenants)
            .map(|i| cand(i as u64, &format!("t{i}"), (i % 3) as u8,
                          i as u64))
            .collect();
        let mut wait = vec![0u64; tenants];
        for round in 0..40 {
            let picked = s.pick(&cs, pool, round);
            for (i, w) in wait.iter_mut().enumerate() {
                if picked.contains(&(i as u64)) {
                    *w = 0;
                } else {
                    *w += 1;
                    assert!(*w <= bound,
                            "tenant t{i} waited {w} > bound {bound}");
                }
            }
        }
    }

    #[test]
    fn idle_tenant_cannot_hoard_credit() {
        let mut s = Scheduler::new(Policy::Fair);
        // Tenant `b` is backlogged alone for many rounds with no free
        // leases... but `a` is absent, so `a` banks nothing.
        let only_b = vec![cand(2, "b", 0, 1)];
        for round in 0..10 {
            s.pick(&only_b, 0, round);
        }
        // When `a` shows up, it does not instantly outrank `b`.
        let both = vec![cand(1, "a", 0, 0), cand(2, "b", 0, 1)];
        assert_eq!(s.pick(&both, 1, 10), vec![2]);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Fair, Policy::Fifo, Policy::Priority] {
            assert_eq!(Policy::from_name(p.name()).unwrap(), p);
        }
        assert!(Policy::from_name("lifo").is_err());
    }
}
