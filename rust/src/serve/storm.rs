//! Seeded request storm: Poisson arrivals of mixed job types.
//!
//! Each tenant gets an independent arrival process forked from the
//! storm seed — exponential inter-arrival gaps (in scheduler rounds),
//! a weighted kind mix (train-heavy, some SFT, some eval), random
//! step demands and priorities, and optional fault injection. The
//! whole storm is a pure function of the config, so
//! `repro serve storm_seed=7` replays the identical workload on every
//! machine — which is what lets CI assert terminal states and
//! fairness on real scheduling, not a mocked queue.

use crate::util::prng::Rng;

use super::job::{JobKind, JobSpec};
use super::ServeConfig;

/// Draw a job kind from the service mix: half pre-train, a third SFT,
/// the rest eval sweeps.
fn draw_kind(rng: &mut Rng) -> JobKind {
    let u = rng.f64();
    if u < 0.5 {
        JobKind::Train
    } else if u < 0.8 {
        JobKind::Sft
    } else {
        JobKind::Eval
    }
}

/// Generate the full storm for a run: `jobs_per_tenant` jobs for each
/// of `tenants` tenants, sorted by arrival round, ids in arrival
/// order.
pub fn generate(cfg: &ServeConfig) -> Vec<JobSpec> {
    let mut rng = Rng::new(cfg.storm_seed);
    let mut specs: Vec<JobSpec> = Vec::new();
    for t in 0..cfg.tenants {
        let mut trng = rng.fork(t as u64);
        let tenant = format!("t{t}");
        let tenant_seed = cfg.storm_seed ^ ((t as u64 + 1) * 0x9E37);
        // Poisson process: exponential gaps between this tenant's
        // arrivals, accumulated into a (rounded-down) round index.
        let mut clock = 0.0f64;
        for _ in 0..cfg.jobs_per_tenant {
            let u = trng.f64();
            clock += -(1.0 - u).ln() * cfg.mean_gap;
            let kind = draw_kind(&mut trng);
            let steps = 4 + trng.below(9) as u64;
            let prio = trng.below(3) as u8;
            let fail_at = if trng.f64() < cfg.fail_rate {
                Some(steps / 2)
            } else {
                None
            };
            specs.push(JobSpec {
                id: 0, // assigned after the arrival sort
                tenant: tenant.clone(),
                tenant_seed,
                kind,
                prio,
                steps,
                arrival_round: clock as u64,
                fail_at,
            });
        }
    }
    specs.sort_by_key(|s| (s.arrival_round, s.tenant.clone()));
    for (i, s) in specs.iter_mut().enumerate() {
        s.id = i as u64;
    }
    specs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig { tenants: 4, jobs_per_tenant: 3, storm_seed: 7,
                      ..Default::default() }
    }

    #[test]
    fn storm_is_deterministic() {
        let a = generate(&cfg());
        let b = generate(&cfg());
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, &x.tenant, x.kind, x.steps,
                        x.arrival_round),
                       (y.id, &y.tenant, y.kind, y.steps,
                        y.arrival_round));
        }
    }

    #[test]
    fn ids_follow_arrival_order_and_every_tenant_appears() {
        let specs = generate(&cfg());
        for w in specs.windows(2) {
            assert!(w[0].arrival_round <= w[1].arrival_round);
            assert!(w[0].id < w[1].id);
        }
        for t in 0..4 {
            let name = format!("t{t}");
            assert_eq!(
                specs.iter().filter(|s| s.tenant == name).count(), 3);
        }
    }

    #[test]
    fn same_tenant_shares_one_seed() {
        let specs = generate(&cfg());
        for t in 0..4 {
            let name = format!("t{t}");
            let seeds: Vec<u64> = specs
                .iter()
                .filter(|s| s.tenant == name)
                .map(|s| s.tenant_seed)
                .collect();
            assert!(seeds.windows(2).all(|w| w[0] == w[1]));
        }
    }

    #[test]
    fn fail_rate_injects_faults() {
        let mut c = cfg();
        c.fail_rate = 1.0;
        assert!(generate(&c).iter().all(|s| s.fail_at.is_some()));
        c.fail_rate = 0.0;
        assert!(generate(&c).iter().all(|s| s.fail_at.is_none()));
    }

    #[test]
    fn different_seeds_differ() {
        let mut c = cfg();
        c.storm_seed = 8;
        let a = generate(&cfg());
        let b = generate(&c);
        assert!(a.iter().zip(&b).any(|(x, y)| {
            x.kind != y.kind || x.steps != y.steps
                || x.arrival_round != y.arrival_round
        }));
    }
}
