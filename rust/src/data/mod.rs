//! Data pipeline: corpora, tokenization, batching.
//!
//! Substitutes OpenWebText/C4 (DESIGN.md §4): a deterministic synthetic
//! corpus with Zipfian unigrams + Markov bigram structure (so there is
//! real next-token signal to learn), plus a small embedded English text
//! for byte-level runs. All optimizers in a comparison consume the
//! identical stream.

pub mod batcher;
pub mod corpus;
pub mod text;

pub use batcher::{Batch, Batcher};
pub use corpus::{Corpus, SyntheticSpec};
