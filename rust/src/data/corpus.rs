//! Token corpora: synthetic (Zipf + Markov) and byte-level text.

use crate::util::prng::{Rng, Zipf};

/// Parameters of the synthetic corpus generator.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub vocab: usize,
    pub n_tokens: usize,
    /// Zipf exponent of the stationary unigram distribution.
    pub zipf_s: f64,
    /// Probability of following the Markov bigram table instead of the
    /// unigram draw — controls how much learnable structure exists.
    pub coherence: f64,
    /// Number of successor candidates per token in the bigram table.
    pub branching: usize,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            vocab: 256,
            n_tokens: 1 << 20,
            zipf_s: 1.05,
            coherence: 0.75,
            branching: 4,
            seed: 0,
        }
    }
}

/// A materialized token stream.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
}

impl Corpus {
    /// Deterministic synthetic corpus: each token has `branching`
    /// preferred successors (drawn once from the Zipf unigram); with
    /// prob `coherence` the next token comes from those, else from the
    /// unigram. This yields a corpus with compressible bigram structure
    /// whose optimal cross-entropy sits well below log(vocab).
    pub fn synthetic(spec: &SyntheticSpec) -> Corpus {
        let mut rng = Rng::new(spec.seed ^ 0xC0FFEE);
        let zipf = Zipf::new(spec.vocab, spec.zipf_s);
        // Bigram successor table.
        let succ: Vec<Vec<usize>> = (0..spec.vocab)
            .map(|_| {
                (0..spec.branching).map(|_| zipf.sample(&mut rng)).collect()
            })
            .collect();
        let mut tokens = Vec::with_capacity(spec.n_tokens);
        let mut prev = zipf.sample(&mut rng);
        for _ in 0..spec.n_tokens {
            let next = if rng.f64() < spec.coherence {
                *rng.choose(&succ[prev])
            } else {
                zipf.sample(&mut rng)
            };
            tokens.push(next as i32);
            prev = next;
        }
        Corpus { vocab: spec.vocab, tokens }
    }

    /// Byte-level corpus from UTF-8 text (vocab 256).
    pub fn from_text(text: &str) -> Corpus {
        Corpus {
            vocab: 256,
            tokens: text.bytes().map(|b| b as i32).collect(),
        }
    }

    /// The embedded English corpus (see `data::text`), repeated to at
    /// least `min_tokens` bytes.
    pub fn embedded_text(min_tokens: usize) -> Corpus {
        let base = super::text::EMBEDDED_CORPUS;
        let mut s = String::with_capacity(min_tokens + base.len());
        while s.len() < min_tokens {
            s.push_str(base);
        }
        Corpus::from_text(&s)
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Empirical unigram entropy (nats) — sanity signal for tests and a
    /// loose lower bound context for training losses.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }

    /// Empirical conditional (bigram) entropy H(X_t | X_{t-1}) in nats —
    /// the achievable-loss floor for a context-1 model.
    pub fn bigram_entropy(&self) -> f64 {
        let v = self.vocab;
        let mut pair = vec![0usize; v * v];
        let mut ctx = vec![0usize; v];
        for w in self.tokens.windows(2) {
            pair[w[0] as usize * v + w[1] as usize] += 1;
            ctx[w[0] as usize] += 1;
        }
        let n = (self.tokens.len() - 1) as f64;
        let mut h = 0.0;
        for a in 0..v {
            if ctx[a] == 0 {
                continue;
            }
            for b in 0..v {
                let c = pair[a * v + b];
                if c == 0 {
                    continue;
                }
                let p_ab = c as f64 / n;
                let p_b_given_a = c as f64 / ctx[a] as f64;
                h -= p_ab * p_b_given_a.ln();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic() {
        let spec = SyntheticSpec { n_tokens: 4096, ..Default::default() };
        let a = Corpus::synthetic(&spec);
        let b = Corpus::synthetic(&spec);
        assert_eq!(a.tokens, b.tokens);
        let spec2 = SyntheticSpec { seed: 1, ..spec };
        let c = Corpus::synthetic(&spec2);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let spec = SyntheticSpec { vocab: 64, n_tokens: 10_000,
                                   ..Default::default() };
        let c = Corpus::synthetic(&spec);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // Coherent corpus must have bigram entropy well below unigram.
        let spec = SyntheticSpec { n_tokens: 200_000, ..Default::default() };
        let c = Corpus::synthetic(&spec);
        let h1 = c.unigram_entropy();
        let h2 = c.bigram_entropy();
        assert!(h2 < 0.8 * h1, "unigram {h1:.3}, bigram {h2:.3}");
        // And coherence=0 removes most of that structure.
        let flat = Corpus::synthetic(&SyntheticSpec {
            coherence: 0.0, n_tokens: 200_000, ..Default::default()
        });
        assert!(flat.bigram_entropy() > 0.9 * flat.unigram_entropy());
    }

    #[test]
    fn embedded_text_repeats_to_size() {
        let c = Corpus::embedded_text(50_000);
        assert!(c.len() >= 50_000);
        assert_eq!(c.vocab, 256);
    }
}
