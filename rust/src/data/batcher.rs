//! Batcher: slices a token stream into (B, S) next-token batches with
//! deterministic per-epoch shuffling.

use crate::data::corpus::Corpus;
use crate::util::prng::Rng;

/// One training batch: `tokens[b][s]` predicts `targets[b][s]`
/// (targets are the stream shifted by one). Stored flat, row-major.
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch_size: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Deterministic epoch-shuffled batcher over non-overlapping windows.
#[derive(Debug, Clone)]
pub struct Batcher {
    corpus: Corpus,
    batch_size: usize,
    seq_len: usize,
    /// Window start offsets for the current epoch order.
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(corpus: Corpus, batch_size: usize, seq_len: usize,
               seed: u64) -> Batcher {
        assert!(corpus.len() > seq_len + 1, "corpus too small");
        // Non-overlapping windows of seq_len+1 (inputs + shifted target).
        let n_windows = (corpus.len() - 1) / seq_len;
        assert!(n_windows >= batch_size,
                "corpus too small for one batch");
        let order: Vec<usize> = (0..n_windows).map(|i| i * seq_len).collect();
        let mut b = Batcher {
            corpus,
            batch_size,
            seq_len,
            order,
            cursor: 0,
            epoch: 0,
            rng: Rng::new(seed ^ 0xBA7C4),
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        let mut rng = self.rng.fork(self.epoch as u64);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch_size
    }

    /// Next batch; rolls into a freshly-shuffled epoch at the boundary.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch_size > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        for i in 0..self.batch_size {
            let start = self.order[self.cursor + i];
            tokens.extend_from_slice(
                &self.corpus.tokens[start..start + self.seq_len]);
            targets.extend_from_slice(
                &self.corpus.tokens[start + 1..start + self.seq_len + 1]);
        }
        self.cursor += self.batch_size;
        Batch {
            batch_size: self.batch_size,
            seq_len: self.seq_len,
            tokens,
            targets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticSpec;
    use crate::util::prop::{check, prop_assert};

    fn corpus(n: usize) -> Corpus {
        Corpus::synthetic(&SyntheticSpec { n_tokens: n,
                                           ..Default::default() })
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut b = Batcher::new(corpus(10_000), 4, 16, 0);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 64);
        // For every row, target[s] should equal the corpus token right
        // after tokens[s] — verified via the corpus itself in the
        // conservation property below; here check shapes & range.
        assert!(batch.tokens.iter().all(|&t| t >= 0 && t < 256));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Batcher::new(corpus(10_000), 4, 16, 42);
        let mut b = Batcher::new(corpus(10_000), 4, 16, 42);
        for _ in 0..10 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn epoch_conservation_property() {
        // Within one epoch, every window is used exactly once.
        check(16, |rng| {
            let seq = 4 + rng.below(12);
            let bs = 1 + rng.below(4);
            let n = (seq + 1) * bs * (2 + rng.below(6)) + seq + 1;
            let mut b = Batcher::new(corpus(n), bs, seq, rng.next_u64());
            let per_epoch = b.batches_per_epoch();
            let mut starts = Vec::new();
            for _ in 0..per_epoch {
                let batch = b.next_batch();
                prop_assert(batch.tokens.len() == bs * seq, "shape")?;
                // Recover window starts via the order bookkeeping:
                // collect first tokens instead — uniqueness proxy:
                starts.push(batch.tokens[0..seq].to_vec());
            }
            prop_assert(b.epoch() == 0, "still in epoch 0")?;
            b.next_batch();
            prop_assert(b.epoch() == 1, "rolled to epoch 1")?;
            Ok(())
        });
    }

    #[test]
    fn shift_property_against_corpus() {
        check(16, |rng| {
            let seq = 4 + rng.below(8);
            let n = 4000 + rng.below(1000);
            let c = corpus(n);
            let reference = c.tokens.clone();
            let mut b = Batcher::new(c, 2, seq, rng.next_u64());
            for _ in 0..5 {
                let batch = b.next_batch();
                for row in 0..2 {
                    let toks = &batch.tokens[row * seq..(row + 1) * seq];
                    let tgts = &batch.targets[row * seq..(row + 1) * seq];
                    // Find this window in the corpus and verify shift.
                    let pos = reference
                        .windows(seq)
                        .position(|w| w == toks)
                        .expect("window must come from corpus");
                    prop_assert(
                        &reference[pos + 1..pos + 1 + seq] == tgts
                            || reference.windows(seq + 1).any(|w| {
                                &w[..seq] == toks && &w[1..] == tgts
                            }),
                        "targets are inputs shifted by one",
                    )?;
                }
            }
            Ok(())
        });
    }
}
