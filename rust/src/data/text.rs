//! Embedded byte-level corpus for "real text" runs (DESIGN.md §4:
//! substitutes OpenWebText/C4, which are unavailable offline).
//!
//! Original prose written for this repository — a plain-English primer
//! on optimization for neural networks, which has the pleasant property
//! that the models being trained are learning to predict text *about*
//! the very algorithms training them.

/// ~6 KiB of original English text; repeated by
/// [`crate::data::Corpus::embedded_text`] to any requested length.
pub const EMBEDDED_CORPUS: &str = "\
Training a neural network is the business of turning a mountain of \
examples into a single set of numbers. The numbers are the weights, the \
mountain is the dataset, and the machinery that moves one toward the \
other is the optimizer. Gradient descent is the oldest such machine. At \
every step it asks the loss function which direction is downhill, takes \
a small step that way, and asks again. The size of the step is the \
learning rate, and choosing it well is most of the art. Too large and \
the iterates ricochet across the valley walls; too small and training \
crawls for weeks.

Momentum was the first great refinement. Instead of following the raw \
gradient, the optimizer follows a running average of recent gradients, \
the way a heavy ball rolling through the valley ignores small bumps. \
The second refinement was adaptivity. Different weights in a network \
live in very different neighborhoods of the loss surface: some \
directions are steep and narrow, others broad and flat. A single \
learning rate must compromise between them. Adaptive methods keep a \
running estimate of the typical squared gradient for every single \
weight, and divide each step by the square root of that estimate. \
Steep coordinates get small steps, flat coordinates get large ones.

Adam combines both ideas: a momentum average of the gradient, and a \
second average of the squared gradient, one scalar of each for every \
parameter in the model. For a network with seven billion weights, that \
is fourteen billion extra numbers that must live in accelerator memory \
for the whole run. The model itself may be quantized, sharded, and \
offloaded, but the optimizer state sits there stubbornly, often \
costing more memory than the weights it serves.

The curious thing, and the observation this corpus exists to \
celebrate, is that most of those fourteen billion numbers may be \
redundant. The loss surface of a neural network is not an arbitrary \
bowl. Its curvature matrix, the Hessian, is very nearly block \
diagonal: weights that feed the same neuron, or the same attention \
head, curve together, while weights in different blocks barely \
interact. Within one dense block, a single well-chosen learning rate \
does the work of thousands of individual ones, and sometimes does it \
better, because a diagonal preconditioner is a poor match for a dense \
block of curvature anyway.

So the recipe is simple to state. Partition the parameters along the \
boundaries the Hessian already drew: queries and keys by attention \
head, values and projections by output neuron, embeddings by token \
row. Give each block one second-moment scalar, the average of the \
squared gradients inside the block. Keep the momentum exactly as Adam \
had it. The optimizer state shrinks by half, almost nothing of the \
training curve changes, and on a crowded GPU the freed memory turns \
into larger batches and fewer communication stalls, which is to say \
into speed.

None of this removes the need for care. The partition must respect \
the architecture: cut along the wrong boundary and blocks mix \
curvature that should stay separate, learning rates average over \
incompatible scales, and the loss spikes at exactly the moment a \
large run can least afford it. Embedding rows for rare tokens see \
gradients only occasionally; transformer blocks near the output see \
sharper curvature than those near the input. The structure is there, \
but it must be read from the network, not imposed on it.

There is a broader lesson in the episode. The fields of numerical \
optimization and deep learning keep meeting in the same place: \
structure. Convergence proofs lean on convexity that networks do not \
have, yet the working heuristics that train them lean on structure \
that networks genuinely do have, in their Hessians, their gradients, \
and their data. Every byte of optimizer state is a bet about where \
that structure lives. Spending fewer bytes, and placing them more \
carefully, is how the bet is won.

A language model reading this paragraph is, at this very moment, the \
subject of the experiment it describes: its own weights are being \
nudged, block by block, by an optimizer that keeps one learning rate \
where its ancestor kept millions. If the loss that produced this \
sentence is falling, the idea works.
";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_nontrivial_ascii() {
        assert!(EMBEDDED_CORPUS.len() > 4000);
        assert!(EMBEDDED_CORPUS.is_ascii());
        // Contains enough distinct bytes to be a real LM target.
        let mut seen = [false; 256];
        for b in EMBEDDED_CORPUS.bytes() {
            seen[b as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() > 25);
    }
}
