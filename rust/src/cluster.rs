//! Simulated multi-GPU cluster — the substrate for the paper's
//! throughput/efficiency claims (Fig 1a, Table 2, Fig 13c).
//!
//! The real testbed (2× A800-80GB) is unavailable; DESIGN.md §4 records
//! the substitution. The simulator keeps the two first-order mechanisms
//! the paper's gains come from:
//!
//! 1. **Memory fitting** — per-GPU memory = weights + grads + (sharded)
//!    optimizer state + activations(batch). Halving optimizer state
//!    admits a larger per-GPU micro-batch.
//! 2. **Batch-efficiency curve** — achieved MFU rises with per-GPU batch
//!    (kernel utilization + amortized per-step communication):
//!    `MFU(bs) = e_max · bs / (bs + b0)`. (e_max, b0) are calibrated once
//!    against the paper's two published Llama-2-7B operating points
//!    (AdamW bs=1 → 3725 tok/s, Adam-mini bs=4 → 5572 tok/s); everything
//!    else (OOM boundaries, other models, other optimizers, GPU-hours)
//!    is *predicted*, not fitted.
//!
//! Optimizer step cost is modeled separately (bytes touched / HBM BW +
//! scalar-op cost) — that term drives the Adafactor-latency comparison
//! of Fig 13c.

use crate::memmodel::ArchSpec;

/// One GPU of the simulated cluster (A800-80GB defaults).
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub mem_bytes: f64,
    /// Peak dense bf16 throughput (flops/s).
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bw: f64,
}

impl GpuSpec {
    pub fn a800_80g() -> GpuSpec {
        GpuSpec {
            mem_bytes: 80e9,
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
        }
    }
}

/// Per-optimizer cost profile for the memory/latency model.
#[derive(Debug, Clone, Copy)]
pub struct OptProfile {
    pub name: &'static str,
    /// Optimizer state, bytes per parameter (float32 states).
    pub state_bytes_per_param: f64,
    /// Bytes moved per parameter per update step (read + write streams).
    pub update_bytes_per_param: f64,
    /// Scalar-op cost per parameter per step, in "expensive-op units"
    /// (sqrt/div/rsqrt count; cheap mul/add ≈ free on GPU).
    pub update_ops_per_param: f64,
}

/// AdamW: state m+v (8 B); streams p,g,m,v read + p,m,v write (28 B);
/// 1 sqrt + 1 div per param.
pub const ADAMW_PROFILE: OptProfile = OptProfile {
    name: "AdamW",
    state_bytes_per_param: 8.0,
    update_bytes_per_param: 28.0,
    update_ops_per_param: 2.0,
};

/// Adam-mini: state m + negligible v_b (~4 B); streams p,g,m read +
/// p,m write (20 B); sqrt/div amortized across each block (≈ 0 per
/// param) — "saves computation when taking the square root of v" (§3.4).
pub const ADAM_MINI_PROFILE: OptProfile = OptProfile {
    name: "Adam-mini",
    state_bytes_per_param: 4.0,
    update_bytes_per_param: 20.0,
    update_ops_per_param: 0.05,
};

/// Adafactor: tiny factored state but TWO reduction passes (rows+cols)
/// over g² plus rsqrt/div/clip per param (§3.4 latency discussion).
pub const ADAFACTOR_PROFILE: OptProfile = OptProfile {
    name: "Adafactor",
    state_bytes_per_param: 4.0, // momentum (paper setup) + factored v
    update_bytes_per_param: 36.0,
    update_ops_per_param: 4.0,
};

// ---------------------------------------------------------------------------
// Collective-traffic closed forms (cross-checked against the measured
// byte counters of the executable `dist` engine — see `repro report`).
// ---------------------------------------------------------------------------

/// Cluster-total bytes a ring all-reduce moves for a `payload_bytes`
/// tensor over `workers` ranks: reduce-scatter + all-gather each move
/// every element `workers − 1` hops, independent of bucket size.
pub fn ring_allreduce_bytes(payload_bytes: f64, workers: usize) -> f64 {
    if workers <= 1 {
        0.0
    } else {
        2.0 * (workers - 1) as f64 * payload_bytes
    }
}

/// Cluster-total bytes a ring all-gather moves: each rank's shard
/// travels `workers − 1` hops, so the full payload moves once per hop.
pub fn ring_allgather_bytes(payload_bytes: f64, workers: usize) -> f64 {
    if workers <= 1 {
        0.0
    } else {
        (workers - 1) as f64 * payload_bytes
    }
}

/// Cluster-total bytes a ring reduce-scatter moves: each per-rank
/// chunk travels `workers − 1` hops being accumulated — exactly half
/// an all-reduce. The ZeRO-2 gradient schedule
/// (reduce-scatter → shard step → param all-gather) therefore moves
/// `2(N−1)·P` bytes per step against ZeRO-1's `3(N−1)·P`
/// (all-reduce + param all-gather).
pub fn ring_reducescatter_bytes(payload_bytes: f64, workers: usize)
    -> f64 {
    if workers <= 1 {
        0.0
    } else {
        (workers - 1) as f64 * payload_bytes
    }
}

/// Wire/dense byte ratio of a gradient codec on SUMMATION messages
/// (reduce-scatter hops, the reduce phase of all-reduce). `frac` is
/// the top-k keep fraction (ignored by the other codecs). f16 packs
/// two half-precision values per f32 wire slot; top-k ships an
/// (index, value) pair — 8 bytes — per kept element, so it only wins
/// below `frac = 0.5`. Per-message header slots are excluded: they
/// are O(1) per hop against O(chunk) payloads, inside the 10%
/// cross-check tolerance of `repro report`.
pub fn codec_sum_ratio(codec: &str, frac: f64) -> f64 {
    match codec {
        "f16" => 0.5,
        "topk" => 2.0 * frac,
        _ => 1.0,
    }
}

/// Wire/dense ratio on BROADCAST messages (all-gather hops, the
/// gather phase of all-reduce). Top-k never compresses broadcasts —
/// re-sparsifying already-reduced values would drop mass with no
/// error-feedback path to recover it — so its broadcast ratio is 1.
pub fn codec_broadcast_ratio(codec: &str) -> f64 {
    match codec {
        "f16" => 0.5,
        _ => 1.0,
    }
}

/// Cluster-total bytes one compressed training step moves for a
/// `payload_bytes` gradient over `workers` ranks. ZeRO-1 runs
/// all-reduce (one sum hop + one broadcast hop per element) plus the
/// param all-gather (broadcast); ZeRO-2 replaces the all-reduce with
/// a single reduce-scatter (sum). Compose with
/// [`retry_overhead_bytes`] for lossy socket links — the ARQ
/// retransmits compressed frames, so the overhead multiplies the
/// compressed base, not the dense one.
pub fn compressed_step_bytes(payload_bytes: f64, workers: usize,
                             zero2: bool, codec: &str, frac: f64)
    -> f64 {
    let sum = codec_sum_ratio(codec, frac);
    let bcast = codec_broadcast_ratio(codec);
    // One hop set: every element travels `workers − 1` links.
    let hop = ring_reducescatter_bytes(payload_bytes, workers);
    if zero2 {
        sum * hop + bcast * hop
    } else {
        (sum + bcast) * hop + bcast * hop
    }
}

/// Expected extra bytes the socket transport's stop-and-wait ARQ
/// retransmits when every data frame is independently lost with
/// probability `p`: a frame needs `1/(1−p)` attempts on average, so
/// retries add `base · p/(1−p)` bytes on top of the base payload
/// (the `retry` ledger class the fault-matrix tests bound).
pub fn retry_overhead_bytes(base_bytes: f64, p_loss: f64) -> f64 {
    assert!((0.0..1.0).contains(&p_loss),
            "loss probability must be in [0, 1)");
    base_bytes * p_loss / (1.0 - p_loss)
}

impl OptProfile {
    /// Bytes of optimizer state a full state synchronization must move
    /// (the ZeRO-1 checkpoint-gather payload). Adam-mini's is half of
    /// AdamW's — the executable form of the paper's state-sharding
    /// communication saving.
    pub fn state_sync_payload(&self, n_params: f64) -> f64 {
        self.state_bytes_per_param * n_params
    }
}

/// A training job on the simulated cluster.
#[derive(Debug, Clone)]
pub struct Job {
    pub n_params: f64,
    pub seq_len: usize,
    pub n_gpus: usize,
    pub gpu: GpuSpec,
    pub opt: OptProfile,
}

/// Batch-efficiency calibration (see module docs).
const E_MAX: f64 = 0.4326;
const B_HALF: f64 = 0.792;
/// Weight/grad precision in the memory model (bf16 weights, fp32 grads —
/// the Torchtitan mixed-precision layout).
const WEIGHT_BYTES: f64 = 2.0;
const GRAD_BYTES: f64 = 4.0;
/// Fixed runtime overhead per GPU (allocator, buffers, kernels).
const OVERHEAD_BYTES: f64 = 2e9;
/// Activation bytes ≈ C_ACT · n_layers · d_model per token (with
/// activation checkpointing at the paper's settings).
const C_ACT: f64 = 7.5;
/// Expensive-op throughput for the optimizer-latency term (ops/s).
const SCALAR_OP_RATE: f64 = 5e12;

impl Job {
    pub fn llama7b(opt: OptProfile) -> Job {
        Job {
            n_params: 6.74e9,
            seq_len: 4096,
            n_gpus: 2,
            gpu: GpuSpec::a800_80g(),
            opt,
        }
    }

    pub fn from_arch(arch: &ArchSpec, n_gpus: usize, opt: OptProfile)
        -> Job {
        Job {
            n_params: arch.n_params() as f64,
            seq_len: arch.seq_len,
            n_gpus,
            gpu: GpuSpec::a800_80g(),
            opt,
        }
    }

    /// Activation memory for one sample (one sequence).
    fn act_bytes_per_sample(&self, layers_times_d: f64) -> f64 {
        C_ACT * layers_times_d * self.seq_len as f64
    }

    /// Approximate layers·d from N (N ≈ 12·L·d² and V·d embeddings; for
    /// the memory model we invert the dense-core heuristic N ≈ 12·L·d²
    /// with d ≈ (N/12/L)^(1/2) folded into a single L·d estimate).
    fn layers_times_d(&self) -> f64 {
        // Empirical fit over the Llama family: L·d ≈ 0.93 · N^0.54.
        0.93 * self.n_params.powf(0.54)
    }

    /// Per-GPU memory at micro-batch `bs` (ZeRO-2: optimizer states
    /// sharded across GPUs; weights and grads replicated).
    pub fn mem_per_gpu(&self, bs: usize) -> f64 {
        let n = self.n_params;
        let states = self.opt.state_bytes_per_param * n
            / self.n_gpus as f64;
        OVERHEAD_BYTES
            + WEIGHT_BYTES * n
            + GRAD_BYTES * n
            + states
            + bs as f64 * self.act_bytes_per_sample(self.layers_times_d())
    }

    /// Largest micro-batch that fits; None if even bs=1 OOMs.
    pub fn max_batch_per_gpu(&self) -> Option<usize> {
        let mut bs = None;
        for b in 1..=512 {
            if self.mem_per_gpu(b) <= self.gpu.mem_bytes {
                bs = Some(b);
            } else {
                break;
            }
        }
        bs
    }

    /// Achieved model-flops utilization at micro-batch `bs`.
    pub fn mfu(&self, bs: usize) -> f64 {
        E_MAX * bs as f64 / (bs as f64 + B_HALF)
    }

    /// Optimizer update time per step (memory-bound stream + scalar ops).
    pub fn opt_step_time(&self) -> f64 {
        let n_local = self.n_params; // states sharded but p/g streams full
        n_local * self.opt.update_bytes_per_param / self.gpu.hbm_bw
            + n_local * self.opt.update_ops_per_param / SCALAR_OP_RATE
    }

    /// Cluster tokens/second at micro-batch `bs`.
    pub fn throughput(&self, bs: usize) -> f64 {
        let tokens_per_gpu = (bs * self.seq_len) as f64;
        let compute = 6.0 * self.n_params * tokens_per_gpu
            / (self.mfu(bs) * self.gpu.peak_flops);
        let step_time = compute + self.opt_step_time();
        self.n_gpus as f64 * tokens_per_gpu / step_time
    }

    /// Throughput at the largest feasible micro-batch.
    pub fn best_throughput(&self) -> Option<(usize, f64)> {
        let bs = self.max_batch_per_gpu()?;
        Some((bs, self.throughput(bs)))
    }

    /// GPU-hours to process `tokens` at best throughput.
    pub fn gpu_hours(&self, tokens: f64) -> Option<f64> {
        let (_, thr) = self.best_throughput()?;
        Some(tokens / thr * self.n_gpus as f64 / 3600.0)
    }
}

// ---------------------------------------------------------------------------
// Multi-tenant shared-base memory model (cross-checked against the
// serve subsystem's live tenant runtimes — see `repro report`).
// ---------------------------------------------------------------------------

/// Parameters of one rank-`r` LoRA adapter over a `d_in × d_out`
/// base: `A ∈ [d_in × r]` plus `B ∈ [r × d_out]`.
pub fn lora_adapter_params(d_in: usize, d_out: usize, rank: usize)
    -> usize {
    rank * (d_in + d_out)
}

/// Bytes to serve `tenants` adapters over ONE shared frozen base:
/// base f32 weights once, plus per-tenant adapter weights and
/// optimizer state. The base contributes no optimizer state (frozen),
/// so the per-tenant cost is tiny and scales with
/// `state_bytes_per_param` — Adam-mini's halved state doubles the
/// tenant density at fixed memory.
pub fn shared_base_bytes(base_params: f64, adapter_params: f64,
                         opt: &OptProfile, tenants: usize) -> f64 {
    4.0 * base_params
        + tenants as f64
            * adapter_params
            * (4.0 + opt.state_bytes_per_param)
}

/// Bytes for the naive alternative: every tenant holds a full
/// trainable replica of the base (weights + optimizer state).
pub fn full_replica_bytes(base_params: f64, opt: &OptProfile,
                          tenants: usize) -> f64 {
    tenants as f64 * base_params * (4.0 + opt.state_bytes_per_param)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_base_beats_replicas_and_scales_linearly() {
        let base = 1024.0 * 1024.0;
        let adapter = lora_adapter_params(1024, 1024, 8) as f64;
        for profile in [&ADAMW_PROFILE, &ADAM_MINI_PROFILE] {
            let one = shared_base_bytes(base, adapter, profile, 1);
            let ten = shared_base_bytes(base, adapter, profile, 10);
            // Marginal tenant cost is exactly the adapter term.
            let marginal = (ten - one) / 9.0;
            let want = adapter * (4.0 + profile.state_bytes_per_param);
            assert!((marginal - want).abs() < 1e-6);
            // Shared base crushes full replication at every scale.
            let rep = full_replica_bytes(base, profile, 10);
            assert!(ten < rep / 5.0, "{} vs {}", ten, rep);
        }
        // Adam-mini packs more tenants than AdamW at fixed memory:
        // its per-tenant marginal bytes are strictly smaller.
        let mini = shared_base_bytes(base, adapter,
                                     &ADAM_MINI_PROFILE, 16);
        let adamw =
            shared_base_bytes(base, adapter, &ADAMW_PROFILE, 16);
        assert!(mini < adamw);
    }

    #[test]
    fn table2_operating_points() {
        // AdamW on 7B/2×A800: only bs=1 fits; Adam-mini: bs=4.
        let aw = Job::llama7b(ADAMW_PROFILE);
        assert_eq!(aw.max_batch_per_gpu(), Some(1));
        let am = Job::llama7b(ADAM_MINI_PROFILE);
        assert_eq!(am.max_batch_per_gpu(), Some(4));
    }

    #[test]
    fn throughput_matches_paper_calibration() {
        let aw = Job::llama7b(ADAMW_PROFILE).best_throughput().unwrap();
        let am = Job::llama7b(ADAM_MINI_PROFILE).best_throughput().unwrap();
        // Paper: 3725.59 vs 5572.19 tok/s (+49.6%).
        assert!((aw.1 - 3725.0).abs() / 3725.0 < 0.05, "adamw {}", aw.1);
        assert!((am.1 - 5572.0).abs() / 5572.0 < 0.05, "mini {}", am.1);
        let gain = am.1 / aw.1 - 1.0;
        assert!((gain - 0.496).abs() < 0.05, "gain {gain}");
    }

    #[test]
    fn gpu_hours_save_about_a_third() {
        // Paper Table 2: 33.1 % wall-clock saving at any token budget.
        let aw = Job::llama7b(ADAMW_PROFILE);
        let am = Job::llama7b(ADAM_MINI_PROFILE);
        let h_aw = aw.gpu_hours(1e9).unwrap();
        let h_am = am.gpu_hours(1e9).unwrap();
        let saving = 1.0 - h_am / h_aw;
        assert!((saving - 0.331).abs() < 0.05, "saving {saving}");
    }

    #[test]
    fn mfu_monotone_in_batch() {
        let j = Job::llama7b(ADAM_MINI_PROFILE);
        let mut prev = 0.0;
        for bs in 1..16 {
            let m = j.mfu(bs);
            assert!(m > prev && m < 0.5);
            prev = m;
        }
    }

    #[test]
    fn more_memory_admits_no_smaller_batch_property() {
        use crate::util::prop::{check, prop_assert};
        check(64, |rng| {
            let n = 1e8 + rng.f64() * 1e10;
            let mut j = Job::llama7b(ADAM_MINI_PROFILE);
            j.n_params = n;
            let small = j.max_batch_per_gpu();
            j.gpu.mem_bytes *= 1.5;
            let big = j.max_batch_per_gpu();
            prop_assert(big.unwrap_or(0) >= small.unwrap_or(0),
                        "monotone in memory")
        });
    }

    #[test]
    fn collective_closed_forms() {
        // Single worker moves nothing.
        assert_eq!(ring_allreduce_bytes(1e6, 1), 0.0);
        assert_eq!(ring_allgather_bytes(1e6, 1), 0.0);
        assert_eq!(ring_reducescatter_bytes(1e6, 1), 0.0);
        // 4 workers: all-reduce 2·3·P, all-gather/reduce-scatter 3·P.
        assert_eq!(ring_allreduce_bytes(1e6, 4), 6e6);
        assert_eq!(ring_allgather_bytes(1e6, 4), 3e6);
        assert_eq!(ring_reducescatter_bytes(1e6, 4), 3e6);
        // ZeRO-2's step total is 2/3 of ZeRO-1's.
        let zero1 = ring_allreduce_bytes(1e6, 4)
            + ring_allgather_bytes(1e6, 4);
        let zero2 = ring_reducescatter_bytes(1e6, 4)
            + ring_allgather_bytes(1e6, 4);
        assert_eq!(zero2, zero1 * 2.0 / 3.0);
        // Adam-mini's state-sync payload is half of AdamW's.
        let n = 1e9;
        assert_eq!(ADAM_MINI_PROFILE.state_sync_payload(n),
                   0.5 * ADAMW_PROFILE.state_sync_payload(n));
        // Retry overhead: no faults → no retries; 20% drop → 1/4 of
        // the base payload again; monotone in the loss rate.
        assert_eq!(retry_overhead_bytes(1e6, 0.0), 0.0);
        assert_eq!(retry_overhead_bytes(1e6, 0.2), 0.25e6);
        assert!(retry_overhead_bytes(1e6, 0.5)
                > retry_overhead_bytes(1e6, 0.2));
    }

    #[test]
    fn compressed_closed_forms() {
        let (p, n) = (1e6, 4usize);
        // compress=none degenerates to the dense forms.
        assert_eq!(
            compressed_step_bytes(p, n, false, "none", 0.0),
            ring_allreduce_bytes(p, n) + ring_allgather_bytes(p, n));
        assert_eq!(
            compressed_step_bytes(p, n, true, "none", 0.0),
            ring_reducescatter_bytes(p, n)
                + ring_allgather_bytes(p, n));
        // f16 halves every phase.
        assert_eq!(
            compressed_step_bytes(p, n, true, "f16", 0.0),
            0.5 * (ring_reducescatter_bytes(p, n)
                   + ring_allgather_bytes(p, n)));
        assert_eq!(
            compressed_step_bytes(p, n, false, "f16", 0.0),
            0.5 * (ring_allreduce_bytes(p, n)
                   + ring_allgather_bytes(p, n)));
        // topk compresses only the sum hops: at frac=0.25 the zero2
        // step moves (0.5 + 1)·(N−1)·P against the dense 2·(N−1)·P.
        let hop = ring_reducescatter_bytes(p, n);
        assert_eq!(compressed_step_bytes(p, n, true, "topk", 0.25),
                   1.5 * hop);
        assert_eq!(compressed_step_bytes(p, n, false, "topk", 0.25),
                   2.5 * hop);
        // The 8-byte pair encoding breaks even at frac = 0.5.
        assert_eq!(codec_sum_ratio("topk", 0.5), 1.0);
        // Single worker moves nothing, compressed or not.
        assert_eq!(compressed_step_bytes(p, 1, false, "f16", 0.0), 0.0);
        // Retry overhead composes on the compressed base.
        let base = compressed_step_bytes(p, n, true, "f16", 0.0);
        assert_eq!(retry_overhead_bytes(base, 0.2), 0.25 * base);
    }

    #[test]
    fn adafactor_step_is_slower_than_mini() {
        let af = Job::llama7b(ADAFACTOR_PROFILE);
        let am = Job::llama7b(ADAM_MINI_PROFILE);
        assert!(af.opt_step_time() > 1.5 * am.opt_step_time());
    }
}
