//! # adam-mini — Rust + JAX + Pallas reproduction of *Adam-mini* (ICLR 2025)
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! - **L3 (this crate)**: the training framework — config system, PJRT
//!   runtime, data pipeline, training coordinator, the full optimizer
//!   roster, and every analysis substrate the paper's evaluation needs
//!   (Hessian structure, quadratic case studies, memory model, cluster
//!   throughput simulator).
//! - **L2/L1 (`python/compile/`)**: JAX transformer + Pallas kernels,
//!   AOT-lowered once to `artifacts/*.hlo.txt`; never on the step path.
//!
//! The public API is organised so a downstream user can: load a model
//! artifact ([`runtime`]), build a dataset ([`data`]), pick an optimizer
//! ([`optim`] + [`partition`]), and train ([`coordinator`]) — or
//! regenerate any paper table/figure ([`experiments`]).
//!
//! Scaling layer: [`dist`] is an executable data-parallel engine —
//! in-process worker threads, bucketed ring collectives (all-reduce,
//! reduce-scatter, all-gather), ZeRO-1/2 sharding, and a streaming
//! bucket pipeline that overlaps collectives with gradient production
//! (`overlap=true`) — driven by the coordinator when a run sets
//! `workers > 1`. Its byte-accounted transport makes the paper's
//! communication claims measurable; `repro report` cross-checks the
//! measured traffic against the analytical [`cluster`] model.

// Numeric-kernel house style: the optimizer/collective inner loops are
// written as explicit indexed loops over parallel flat arrays (the
// index IS the arena coordinate); iterator rewrites obscure that. CI
// runs clippy with -D warnings under these carve-outs.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod eval;
pub mod experiments;
pub mod hessian;
pub mod linalg;
pub mod memmodel;
pub mod optim;
pub mod partition;
pub mod quadratic;
pub mod rlhf;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
