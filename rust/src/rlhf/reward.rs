//! Programmatic preference reward (substitutes the trained
//! ultrafeedback reward model; DESIGN.md §4).
//!
//! The reward prefers responses that (a) stay on the prompt's token
//! distribution (bigram continuity), (b) avoid immediate repetition,
//! and (c) use "preferred" vocabulary (a fixed token subset). It is
//! deterministic, bounded, and dense enough for REINFORCE-style
//! optimization to make measurable progress in hundreds of steps.

/// Reward configuration.
#[derive(Debug, Clone)]
pub struct RewardSpec {
    pub vocab: usize,
    /// Tokens in [0, vocab·preferred_frac) earn the vocabulary bonus.
    pub preferred_frac: f64,
    pub repetition_penalty: f64,
    pub continuity_bonus: f64,
}

impl Default for RewardSpec {
    fn default() -> Self {
        RewardSpec {
            vocab: 256,
            preferred_frac: 0.25,
            repetition_penalty: 1.0,
            continuity_bonus: 0.5,
        }
    }
}

/// Score one response given its prompt. Bounded in roughly [−2, 2].
pub fn preference_reward(spec: &RewardSpec, prompt: &[i32],
                         response: &[i32]) -> f64 {
    if response.is_empty() {
        return -2.0;
    }
    let cutoff = (spec.vocab as f64 * spec.preferred_frac) as i32;
    let n = response.len() as f64;

    // Vocabulary preference.
    let pref = response.iter().filter(|&&t| t < cutoff).count() as f64 / n;

    // Immediate-repetition penalty.
    let reps = response
        .windows(2)
        .filter(|w| w[0] == w[1])
        .count() as f64
        / n.max(1.0);

    // Continuity: response reuses tokens that appeared in the prompt
    // (proxy for topicality).
    let mut seen = vec![false; spec.vocab];
    for &t in prompt {
        seen[t as usize] = true;
    }
    let cont = response
        .iter()
        .filter(|&&t| seen[t as usize])
        .count() as f64
        / n;

    2.0 * pref - spec.repetition_penalty * 2.0 * reps
        + spec.continuity_bonus * cont - 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefers_preferred_vocab() {
        let spec = RewardSpec::default();
        let prompt = [1, 2, 3];
        let good: Vec<i32> = (0..16).map(|i| (i % 30) as i32).collect();
        let bad: Vec<i32> = (0..16).map(|i| 200 + (i % 30) as i32).collect();
        assert!(preference_reward(&spec, &prompt, &good)
                > preference_reward(&spec, &prompt, &bad));
    }

    #[test]
    fn penalizes_repetition() {
        let spec = RewardSpec::default();
        let varied: Vec<i32> = (0..16).map(|i| i as i32).collect();
        let repeated = vec![7i32; 16];
        assert!(preference_reward(&spec, &[], &varied)
                > preference_reward(&spec, &[], &repeated));
    }

    #[test]
    fn bounded_and_deterministic() {
        let spec = RewardSpec::default();
        let r1 = preference_reward(&spec, &[1, 2], &[3, 4, 5]);
        let r2 = preference_reward(&spec, &[1, 2], &[3, 4, 5]);
        assert_eq!(r1, r2);
        assert!((-3.0..=3.0).contains(&r1));
        assert_eq!(preference_reward(&spec, &[], &[]), -2.0);
    }
}
