//! Autoregressive sampling through the AOT `logits` artifact.
//!
//! The artifact computes full-sequence logits at the model's fixed
//! (B, S); decoding fills token positions left→right, re-running the
//! graph per position — O(S) forwards per rollout, fine at probe scale
//! (a KV-cache decode graph is the production path on real hardware).

use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::runtime::engine::{lit_i32, tensor_to_lit, Executable};
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::Tensor;
use crate::util::prng::Rng;

pub struct Sampler {
    exe: Rc<Executable>,
    pub batch_size: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl Sampler {
    pub fn new(engine: &Engine, rt: &ModelRuntime) -> Result<Sampler> {
        Ok(Sampler {
            exe: engine.load(&rt.mm.name, "logits")?,
            batch_size: rt.mm.batch_size,
            seq_len: rt.mm.seq_len,
            vocab: rt.mm.vocab,
        })
    }

    /// Full-sequence logits: tokens (B·S) -> logits (B·S·V) flat.
    pub fn logits(&self, params: &[Tensor], tokens: &[i32])
        -> Result<Vec<f32>> {
        let mut args =
            vec![lit_i32(&[self.batch_size, self.seq_len], tokens)?];
        for p in params {
            args.push(tensor_to_lit(p)?);
        }
        let outs = self.exe.run(&args)?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// Complete each row's prompt (first `prompt_len` tokens are kept)
    /// by sampling (temperature > 0) or greedy decoding (temperature 0).
    /// Returns the full (B, S) token matrix.
    pub fn complete(&self, params: &[Tensor], prompts: &[i32],
                    prompt_len: usize, temperature: f32, rng: &mut Rng)
        -> Result<Vec<i32>> {
        let (b, s, v) = (self.batch_size, self.seq_len, self.vocab);
        assert_eq!(prompts.len(), b * s);
        let mut tokens = prompts.to_vec();
        for pos in prompt_len..s {
            let logits = self.logits(params, &tokens)?;
            for row in 0..b {
                // Next-token distribution comes from position pos−1.
                let off = (row * s + pos - 1) * v;
                let slice = &logits[off..off + v];
                let next = if temperature <= 0.0 {
                    argmax(slice)
                } else {
                    sample_categorical(slice, temperature, rng)
                };
                tokens[row * s + pos] = next as i32;
            }
        }
        Ok(tokens)
    }
}

fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

fn sample_categorical(logits: &[f32], temperature: f32, rng: &mut Rng)
    -> usize {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let probs: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - mx) / temperature) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.f64() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sampling_basics() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        let mut rng = Rng::new(0);
        // Sampling from a near-deterministic distribution returns the
        // mode almost always.
        let logits = [0.0f32, 20.0, 0.0, 0.0];
        let hits = (0..100)
            .filter(|_| sample_categorical(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 95);
        // High temperature spreads mass.
        let spread: std::collections::HashSet<usize> = (0..200)
            .map(|_| sample_categorical(&logits, 50.0, &mut rng))
            .collect();
        assert!(spread.len() >= 3);
    }
}
