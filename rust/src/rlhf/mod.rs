//! Alignment pipeline (paper §3.3 / Fig 12): SFT with prompt masking,
//! a programmatic preference reward, and ReMax (Li et al. 2023) —
//! REINFORCE with a greedy-rollout baseline — all driven through the
//! AOT `logits` and `grad_weighted` artifacts.
//!
//! DESIGN.md §4: the pretrained-7B + ultrafeedback stack is substituted
//! by a tiny in-repo pretrained LM + a deterministic preference reward;
//! the optimizer code paths (masked-SFT gradients, reward ascent,
//! per-sequence advantages) are the real thing.

pub mod lora;
pub mod remax;
pub mod reward;
pub mod sampler;
pub mod sft;

pub use lora::LoraGrad;
pub use remax::{remax_train, RemaxConfig};
pub use reward::{preference_reward, RewardSpec};
pub use sampler::Sampler;
pub use sft::{sft_train, SftConfig};
