//! ReMax (Li et al. 2023): REINFORCE with a greedy-rollout baseline —
//! the paper's RLHF algorithm (§3.3, memory-efficient PPO alternative).
//!
//! For each prompt x: sample y ~ π_θ (temperature 1), greedy ȳ = argmax
//! rollout as the variance-reducing baseline; advantage A = r(y) − r(ȳ);
//! gradient = A · ∇(−log π_θ(y)) — realized through the
//! `grad_weighted` artifact with per-token weights A·mask(response).

use anyhow::Result;

use crate::data::{Batcher, Corpus, SyntheticSpec};
use crate::optim::Optimizer;
use crate::rlhf::reward::{preference_reward, RewardSpec};
use crate::rlhf::sampler::Sampler;
use crate::rlhf::sft::WeightedGrad;
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::Tensor;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct RemaxConfig {
    pub steps: usize,
    pub prompt_len: usize,
    pub lr: f32,
    pub temperature: f32,
    pub seed: u64,
    pub reward: RewardSpec,
}

impl Default for RemaxConfig {
    fn default() -> Self {
        RemaxConfig {
            steps: 60,
            prompt_len: 24,
            lr: 5e-5,
            temperature: 1.0,
            seed: 0,
            reward: RewardSpec::default(),
        }
    }
}

/// Per-step record: mean sampled reward and mean baseline reward.
#[derive(Debug, Clone)]
pub struct RemaxLog {
    pub step: usize,
    pub mean_reward: f64,
    pub baseline_reward: f64,
}

/// Run ReMax; returns the reward curve.
pub fn remax_train(engine: &Engine, rt: &ModelRuntime,
                   params: &mut Vec<Tensor>, opt: &mut dyn Optimizer,
                   cfg: &RemaxConfig) -> Result<Vec<RemaxLog>> {
    let sampler = Sampler::new(engine, rt)?;
    let wg = WeightedGrad::new(engine, rt)?;
    let (b, s) = (rt.mm.batch_size, rt.mm.seq_len);
    let mut rng = Rng::new(cfg.seed ^ 0x4E4AC);

    // Prompt source: the pre-training distribution.
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: (cfg.steps + 8) * b * s + 4096,
        seed: cfg.seed ^ 0xF00D,
        ..Default::default()
    });
    let mut prompts = Batcher::new(corpus, b, s, cfg.seed);
    let mut logs = Vec::with_capacity(cfg.steps);

    for step in 1..=cfg.steps {
        let batch = prompts.next_batch();
        // Stochastic rollout + greedy baseline from the same prompts.
        let sampled = sampler.complete(params, &batch.tokens,
                                       cfg.prompt_len, cfg.temperature,
                                       &mut rng)?;
        let greedy = sampler.complete(params, &batch.tokens,
                                      cfg.prompt_len, 0.0, &mut rng)?;
        // Per-sequence advantages.
        let mut advantages = Vec::with_capacity(b);
        let mut r_sum = 0.0;
        let mut base_sum = 0.0;
        for row in 0..b {
            let prompt = &sampled[row * s..row * s + cfg.prompt_len];
            let resp = &sampled[row * s + cfg.prompt_len..(row + 1) * s];
            let resp_g = &greedy[row * s + cfg.prompt_len..(row + 1) * s];
            let r = preference_reward(&cfg.reward, prompt, resp);
            let rb = preference_reward(&cfg.reward, prompt, resp_g);
            r_sum += r;
            base_sum += rb;
            advantages.push((r - rb) as f32);
        }
        // REINFORCE weights: advantage on response positions. The CE
        // loss is −log π(target | ctx); ascending reward means
        // *descending* A·(−log π), so weights carry +A.
        let resp_frac = (s - cfg.prompt_len) as f32 / s as f32;
        let mut weights = vec![0.0f32; b * s];
        // targets[pos] predicts token at pos+1 → response tokens are
        // predicted at positions prompt_len-1 .. s-1.
        for row in 0..b {
            for pos in cfg.prompt_len - 1..s - 1 {
                weights[row * s + pos] = advantages[row] / resp_frac;
            }
        }
        // Targets: the sampled sequence shifted by one.
        let mut targets = vec![0i32; b * s];
        for row in 0..b {
            for pos in 0..s - 1 {
                targets[row * s + pos] = sampled[row * s + pos + 1];
            }
        }
        let (_, grads) = wg.grad(params, &sampled, &targets, &weights)?;
        opt.step(params, &grads, cfg.lr);
        logs.push(RemaxLog {
            step,
            mean_reward: r_sum / b as f64,
            baseline_reward: base_sum / b as f64,
        });
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    #[test]
    fn advantage_weights_are_zero_on_prompt() {
        // Structural check of the weight layout logic.
        let (b, s, prompt) = (2usize, 8usize, 3usize);
        let advantages = [0.5f32, -1.0];
        let resp_frac = (s - prompt) as f32 / s as f32;
        let mut weights = vec![0.0f32; b * s];
        for row in 0..b {
            for pos in prompt - 1..s - 1 {
                weights[row * s + pos] = advantages[row] / resp_frac;
            }
        }
        assert_eq!(weights[0], 0.0);
        assert_eq!(weights[1], 0.0);
        assert!(weights[2] > 0.0);
        assert_eq!(weights[7], 0.0); // last position predicts nothing
        assert!(weights[8 + 2] < 0.0);
    }
}
