//! LoRA fine-tuning substrate (paper Fig 22 / Table 5 "SFT (LoRA)"):
//! low-rank adapters on the attention matrices, gradients through the
//! `grad_lora` artifact, optimizer steps on the adapters only.

use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::runtime::engine::{lit_i32, lit_to_scalar, lit_to_tensor,
                             tensor_to_lit, Executable};
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::Tensor;
use crate::util::prng::Rng;

pub struct LoraGrad {
    exe: Rc<Executable>,
    pub batch_size: usize,
    pub seq_len: usize,
    n_base: usize,
    n_adapters: usize,
}

impl LoraGrad {
    pub fn new(engine: &Engine, rt: &ModelRuntime) -> Result<LoraGrad> {
        let exe = engine.load(&rt.mm.name, "grad_lora")?;
        let n_base = rt.mm.params.len();
        let n_adapters = exe.inputs.len() - 2 - n_base;
        Ok(LoraGrad {
            exe,
            batch_size: rt.mm.batch_size,
            seq_len: rt.mm.seq_len,
            n_base,
            n_adapters,
        })
    }

    /// Fresh adapters: A ~ N(0, 0.02), B = 0 (the standard LoRA init —
    /// the adapted model starts exactly at the base model).
    pub fn init_adapters(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed ^ 0x10A);
        self.exe.inputs[2 + self.n_base..]
            .iter()
            .map(|s| {
                if s.name.starts_with("lora_a") {
                    Tensor::randn(&*s.name, &s.shape, 0.02, &mut rng)
                } else {
                    Tensor::zeros(&*s.name, &s.shape)
                }
            })
            .collect()
    }

    /// loss + adapter gradients (base params frozen).
    pub fn grad(&self, base: &[Tensor], adapters: &[Tensor],
                tokens: &[i32], targets: &[i32])
        -> Result<(f32, Vec<Tensor>)> {
        if adapters.len() != self.n_adapters {
            return Err(anyhow!("expected {} adapters, got {}",
                               self.n_adapters, adapters.len()));
        }
        let shape = [self.batch_size, self.seq_len];
        let mut args = vec![lit_i32(&shape, tokens)?,
                            lit_i32(&shape, targets)?];
        for p in base.iter().chain(adapters) {
            args.push(tensor_to_lit(p)?);
        }
        let outs = self.exe.run(&args)?;
        let loss = lit_to_scalar(&outs[0])?;
        let grads = outs[1..]
            .iter()
            .zip(&self.exe.outputs[1..])
            .map(|(l, s)| lit_to_tensor(l, s))
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }
}
