//! Supervised fine-tuning with prompt masking (paper §3.3 setup):
//! loss is computed on response tokens only, via the `grad_weighted`
//! artifact's per-token weights.

use std::rc::Rc;

use anyhow::Result;

use crate::data::{Batcher, Corpus, SyntheticSpec};
use crate::optim::{Optimizer, Schedule};
use crate::runtime::engine::{lit_f32, lit_i32, lit_to_scalar,
                             lit_to_tensor, tensor_to_lit, Executable};
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct SftConfig {
    pub steps: usize,
    pub prompt_len: usize,
    pub peak_lr: f32,
    pub seed: u64,
}

impl Default for SftConfig {
    fn default() -> Self {
        SftConfig { steps: 120, prompt_len: 24, peak_lr: 2e-4, seed: 0 }
    }
}

/// Weighted-grad step handle.
pub struct WeightedGrad {
    exe: Rc<Executable>,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl WeightedGrad {
    pub fn new(engine: &Engine, rt: &ModelRuntime) -> Result<WeightedGrad> {
        Ok(WeightedGrad {
            exe: engine.load(&rt.mm.name, "grad_weighted")?,
            batch_size: rt.mm.batch_size,
            seq_len: rt.mm.seq_len,
        })
    }

    pub fn grad(&self, params: &[Tensor], tokens: &[i32], targets: &[i32],
                weights: &[f32]) -> Result<(f32, Vec<Tensor>)> {
        let shape = [self.batch_size, self.seq_len];
        let mut args = vec![
            lit_i32(&shape, tokens)?,
            lit_i32(&shape, targets)?,
            lit_f32(&shape, weights)?,
        ];
        for p in params {
            args.push(tensor_to_lit(p)?);
        }
        let outs = self.exe.run(&args)?;
        let loss = lit_to_scalar(&outs[0])?;
        let grads = outs[1..]
            .iter()
            .zip(&self.exe.outputs[1..])
            .map(|(l, s)| lit_to_tensor(l, s))
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }
}

/// Response-only weight mask for a (B, S) batch: 0 on the first
/// `prompt_len` positions, `scale` after. `scale` renormalizes so the
/// masked mean matches an unmasked mean's magnitude.
pub fn response_mask(batch_size: usize, seq_len: usize, prompt_len: usize)
    -> Vec<f32> {
    let resp = (seq_len - prompt_len) as f32;
    let scale = seq_len as f32 / resp;
    let mut w = vec![0.0f32; batch_size * seq_len];
    for b in 0..batch_size {
        for s in prompt_len..seq_len {
            w[b * seq_len + s] = scale;
        }
    }
    w
}

/// SFT run: fine-tune `params` on an instruction-style corpus (a
/// *different* synthetic distribution than pre-training, so there is a
/// real domain gap to close). Returns per-step masked losses.
pub fn sft_train(engine: &Engine, rt: &ModelRuntime,
                 params: &mut Vec<Tensor>, opt: &mut dyn Optimizer,
                 cfg: &SftConfig) -> Result<Vec<f32>> {
    let wg = WeightedGrad::new(engine, rt)?;
    // SFT corpus: higher coherence + different seed = shifted domain.
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: (cfg.steps + 16) * rt.mm.batch_size * rt.mm.seq_len / 2
            + 4096,
        coherence: 0.92,
        branching: 2,
        seed: cfg.seed ^ 0x5F7,
        ..Default::default()
    });
    let mut batcher = Batcher::new(corpus, rt.mm.batch_size,
                                   rt.mm.seq_len, cfg.seed);
    let mask = response_mask(rt.mm.batch_size, rt.mm.seq_len,
                             cfg.prompt_len);
    let schedule = Schedule::WarmupCosine {
        peak: cfg.peak_lr,
        min_lr: cfg.peak_lr / 10.0,
        warmup: (cfg.steps / 20).max(1),
        total: cfg.steps,
    };
    let mut losses = Vec::with_capacity(cfg.steps);
    for t in 1..=cfg.steps {
        let b = batcher.next_batch();
        let (loss, grads) = wg.grad(params, &b.tokens, &b.targets, &mask)?;
        opt.step(params, &grads, schedule.lr(t));
        losses.push(loss);
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_zeroes_prompt_and_renormalizes() {
        let w = response_mask(2, 8, 3);
        assert_eq!(w.len(), 16);
        assert!(w[..3].iter().all(|&x| x == 0.0));
        assert!(w[3..8].iter().all(|&x| (x - 1.6).abs() < 1e-6));
        // Mean over a row equals 1 (so masked loss is comparable).
        let mean: f32 = w[..8].iter().sum::<f32>() / 8.0;
        assert!((mean - 1.0).abs() < 1e-6);
    }
}
