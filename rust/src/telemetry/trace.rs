//! Trace recording: JSONL sink (one event per line) and a
//! Chrome-trace-format exporter so step/bucket/collective spans open
//! in about://tracing.
//!
//! Schema (version 1): every line is a flat JSON object carrying
//! `{"v":1,"seq":N,"t_us":T,"ev":KIND,...}`. The first line is a
//! `trace_begin` header, the last a `trace_end` footer with the bus's
//! published/dropped totals — `validate` checks that sequence numbers
//! are strictly increasing and that the total gap count never exceeds
//! the reported drops (the bus assigns `seq` under the same lock that
//! drops, so a clean trace can have gaps only where drops happened).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::event::{intern_class, intern_codec, Event, Stamped};

/// Trace schema version written into every line.
pub const TRACE_VERSION: u64 = 1;

fn base_obj(st: &Stamped) -> Vec<(&'static str, Json)> {
    vec![
        ("v", Json::num(TRACE_VERSION as f64)),
        ("seq", Json::num(st.seq as f64)),
        ("t_us", Json::num(st.t_us)),
        ("ev", Json::str(st.event.kind())),
    ]
}

/// Encode one stamped event as a single flat JSON line.
pub fn encode_line(st: &Stamped) -> String {
    let mut kv = base_obj(st);
    match &st.event {
        Event::StepBegin { step, n_micro, workers } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("n_micro", Json::num(*n_micro as f64)));
            kv.push(("workers", Json::num(*workers as f64)));
        }
        Event::StepEnd { step, wall_ns } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("wall_ns", Json::num(*wall_ns)));
        }
        Event::BucketReady { step, bucket, spans, elems } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("bucket", Json::num(*bucket as f64)));
            kv.push(("spans", Json::num(*spans as f64)));
            kv.push(("elems", Json::num(*elems as f64)));
        }
        Event::CollectiveLaunched { step, rank, bucket, class, bytes } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("bucket", Json::num(*bucket as f64)));
            kv.push(("class", Json::str(*class)));
            kv.push(("bytes", Json::num(*bytes as f64)));
        }
        Event::CollectiveLanded { step, rank, bucket, class, bytes, ns } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("bucket", Json::num(*bucket as f64)));
            kv.push(("class", Json::str(*class)));
            kv.push(("bytes", Json::num(*bytes as f64)));
            kv.push(("ns", Json::num(*ns)));
        }
        Event::ShardStepped { step, rank, bucket, lo, hi } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("bucket", Json::num(*bucket as f64)));
            kv.push(("lo", Json::num(*lo as f64)));
            kv.push(("hi", Json::num(*hi as f64)));
        }
        Event::LossReported { step, rank, loss, lr } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("loss", Json::num(*loss)));
            kv.push(("lr", Json::num(*lr)));
        }
        Event::CheckpointSaved { step, path } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("path", Json::str(path.clone())));
        }
        Event::Message { rank, class, bytes } => {
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("class", Json::str(*class)));
            kv.push(("bytes", Json::num(*bytes as f64)));
        }
        Event::ArtifactLoaded { name, ms } => {
            kv.push(("name", Json::str(name.clone())));
            kv.push(("ms", Json::num(*ms)));
        }
        Event::RetrySent { rank, peer, class, seq, attempt, bytes } => {
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("peer", Json::num(*peer as f64)));
            kv.push(("class", Json::str(*class)));
            kv.push(("frame_seq", Json::num(*seq as f64)));
            kv.push(("attempt", Json::num(*attempt as f64)));
            kv.push(("bytes", Json::num(*bytes as f64)));
        }
        Event::CommTimeout { rank, peer, class, seq, attempts } => {
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("peer", Json::num(*peer as f64)));
            kv.push(("class", Json::str(*class)));
            kv.push(("frame_seq", Json::num(*seq as f64)));
            kv.push(("attempts", Json::num(*attempts as f64)));
        }
        Event::CommHangup { step, rank } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
        }
        Event::BucketCompressed {
            step, rank, bucket, codec, raw_bytes, wire_bytes,
        } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("bucket", Json::num(*bucket as f64)));
            kv.push(("codec", Json::str(*codec)));
            kv.push(("raw_bytes", Json::num(*raw_bytes as f64)));
            kv.push(("wire_bytes", Json::num(*wire_bytes as f64)));
        }
        Event::ResidualNorm { step, rank, norm } => {
            kv.push(("step", Json::num(*step as f64)));
            kv.push(("rank", Json::num(*rank as f64)));
            kv.push(("norm", Json::num(*norm)));
        }
        Event::JobQueued { job, tenant, kind, round } => {
            kv.push(("job", Json::num(*job as f64)));
            kv.push(("tenant", Json::str(tenant.clone())));
            kv.push(("kind", Json::str(kind.clone())));
            kv.push(("round", Json::num(*round as f64)));
        }
        Event::JobStarted { job, tenant, lease, round } => {
            kv.push(("job", Json::num(*job as f64)));
            kv.push(("tenant", Json::str(tenant.clone())));
            kv.push(("lease", Json::num(*lease as f64)));
            kv.push(("round", Json::num(*round as f64)));
        }
        Event::JobPreempted { job, tenant, at_step, round } => {
            kv.push(("job", Json::num(*job as f64)));
            kv.push(("tenant", Json::str(tenant.clone())));
            kv.push(("at_step", Json::num(*at_step as f64)));
            kv.push(("round", Json::num(*round as f64)));
        }
        Event::JobFinished { job, tenant, outcome, steps, rounds } => {
            kv.push(("job", Json::num(*job as f64)));
            kv.push(("tenant", Json::str(tenant.clone())));
            kv.push(("outcome", Json::str(outcome.clone())));
            kv.push(("steps", Json::num(*steps as f64)));
            kv.push(("rounds", Json::num(*rounds as f64)));
        }
    }
    Json::obj(kv).to_string()
}

/// One decoded trace line. Distinguishing `Unknown` from a parse
/// error is the forward-compat contract: a reader built before a new
/// event kind existed must still be able to audit the trace's
/// sequence numbers (the gap-vs-drop invariant is kind-agnostic), so
/// unknown kinds carry their `seq` instead of failing the whole read.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceLine {
    /// A known event.
    Event(Stamped),
    /// The `trace_begin` / `trace_end` envelope (footer carries the
    /// bus totals).
    Envelope(Json),
    /// A line written by a newer binary: well-formed v1 line whose
    /// `ev` kind this reader does not recognize.
    Unknown { seq: u64, kind: String },
}

/// Decode one JSONL line back into a stamped event. Header/footer
/// lines (`trace_begin` / `trace_end`) return `Ok(None)`; lines with
/// an unknown event kind are an error here — use [`decode_record`]
/// for the forward-compatible reader.
pub fn decode_line(line: &str) -> Result<Option<Stamped>> {
    match decode_record(line)? {
        TraceLine::Event(st) => Ok(Some(st)),
        TraceLine::Envelope(_) => Ok(None),
        TraceLine::Unknown { kind, .. } => {
            bail!("unknown event kind {kind:?}")
        }
    }
}

/// Decode one JSONL line, tolerating event kinds from the future.
pub fn decode_record(line: &str) -> Result<TraceLine> {
    let j = Json::parse(line).context("unparseable trace line")?;
    let v = j.get("v")?.as_usize()? as u64;
    if v != TRACE_VERSION {
        bail!("trace schema v{v} (reader supports v{TRACE_VERSION})");
    }
    let ev = j.get("ev")?.as_str()?.to_string();
    if ev == "trace_begin" || ev == "trace_end" {
        return Ok(TraceLine::Envelope(j));
    }
    let seq = j.get("seq")?.as_usize()? as u64;
    let t_us = j.get("t_us")?.as_f64()?;
    let step = |j: &Json| -> Result<u64> {
        Ok(j.get("step")?.as_usize()? as u64)
    };
    let rank = |j: &Json| -> Result<usize> { j.get("rank")?.as_usize() };
    let event = match ev.as_str() {
        "step_begin" => Event::StepBegin {
            step: step(&j)?,
            n_micro: j.get("n_micro")?.as_usize()?,
            workers: j.get("workers")?.as_usize()?,
        },
        "step_end" => Event::StepEnd {
            step: step(&j)?,
            wall_ns: j.get("wall_ns")?.as_f64()?,
        },
        "bucket_ready" => Event::BucketReady {
            step: step(&j)?,
            bucket: j.get("bucket")?.as_usize()?,
            spans: j.get("spans")?.as_usize()?,
            elems: j.get("elems")?.as_usize()?,
        },
        "collective_launched" => Event::CollectiveLaunched {
            step: step(&j)?,
            rank: rank(&j)?,
            bucket: j.get("bucket")?.as_usize()?,
            class: intern_class(j.get("class")?.as_str()?),
            bytes: j.get("bytes")?.as_usize()? as u64,
        },
        "collective_landed" => Event::CollectiveLanded {
            step: step(&j)?,
            rank: rank(&j)?,
            bucket: j.get("bucket")?.as_usize()?,
            class: intern_class(j.get("class")?.as_str()?),
            bytes: j.get("bytes")?.as_usize()? as u64,
            ns: j.get("ns")?.as_f64()?,
        },
        "shard_stepped" => Event::ShardStepped {
            step: step(&j)?,
            rank: rank(&j)?,
            bucket: j.get("bucket")?.as_f64()? as i64,
            lo: j.get("lo")?.as_usize()?,
            hi: j.get("hi")?.as_usize()?,
        },
        "loss" => Event::LossReported {
            step: step(&j)?,
            rank: j.get("rank")?.as_f64()? as i64,
            loss: j.get("loss")?.as_f64()?,
            lr: j.get("lr")?.as_f64()?,
        },
        "checkpoint" => Event::CheckpointSaved {
            step: step(&j)?,
            path: j.get("path")?.as_str()?.to_string(),
        },
        "message" => Event::Message {
            rank: rank(&j)?,
            class: intern_class(j.get("class")?.as_str()?),
            bytes: j.get("bytes")?.as_usize()? as u64,
        },
        "artifact" => Event::ArtifactLoaded {
            name: j.get("name")?.as_str()?.to_string(),
            ms: j.get("ms")?.as_f64()?,
        },
        "retry_sent" => Event::RetrySent {
            rank: rank(&j)?,
            peer: j.get("peer")?.as_usize()?,
            class: intern_class(j.get("class")?.as_str()?),
            seq: j.get("frame_seq")?.as_usize()? as u64,
            attempt: j.get("attempt")?.as_usize()? as u64,
            bytes: j.get("bytes")?.as_usize()? as u64,
        },
        "comm_timeout" => Event::CommTimeout {
            rank: rank(&j)?,
            peer: j.get("peer")?.as_usize()?,
            class: intern_class(j.get("class")?.as_str()?),
            seq: j.get("frame_seq")?.as_usize()? as u64,
            attempts: j.get("attempts")?.as_usize()? as u64,
        },
        "comm_hangup" => Event::CommHangup {
            step: step(&j)?,
            rank: rank(&j)?,
        },
        "bucket_compressed" => Event::BucketCompressed {
            step: step(&j)?,
            rank: rank(&j)?,
            bucket: j.get("bucket")?.as_f64()? as i64,
            codec: intern_codec(j.get("codec")?.as_str()?),
            raw_bytes: j.get("raw_bytes")?.as_usize()? as u64,
            wire_bytes: j.get("wire_bytes")?.as_usize()? as u64,
        },
        "residual_norm" => Event::ResidualNorm {
            step: step(&j)?,
            rank: rank(&j)?,
            norm: j.get("norm")?.as_f64()?,
        },
        "job_queued" => Event::JobQueued {
            job: j.get("job")?.as_usize()? as u64,
            tenant: j.get("tenant")?.as_str()?.to_string(),
            kind: j.get("kind")?.as_str()?.to_string(),
            round: j.get("round")?.as_usize()? as u64,
        },
        "job_started" => Event::JobStarted {
            job: j.get("job")?.as_usize()? as u64,
            tenant: j.get("tenant")?.as_str()?.to_string(),
            lease: j.get("lease")?.as_usize()?,
            round: j.get("round")?.as_usize()? as u64,
        },
        "job_preempted" => Event::JobPreempted {
            job: j.get("job")?.as_usize()? as u64,
            tenant: j.get("tenant")?.as_str()?.to_string(),
            at_step: j.get("at_step")?.as_usize()? as u64,
            round: j.get("round")?.as_usize()? as u64,
        },
        "job_finished" => Event::JobFinished {
            job: j.get("job")?.as_usize()? as u64,
            tenant: j.get("tenant")?.as_str()?.to_string(),
            outcome: j.get("outcome")?.as_str()?.to_string(),
            steps: j.get("steps")?.as_usize()? as u64,
            rounds: j.get("rounds")?.as_usize()? as u64,
        },
        other => {
            return Ok(TraceLine::Unknown {
                seq,
                kind: other.to_string(),
            })
        }
    };
    Ok(TraceLine::Event(Stamped { seq, t_us, event }))
}

/// Buffered JSONL trace sink.
pub struct TraceWriter {
    w: BufWriter<File>,
    pub path: PathBuf,
    lines: u64,
}

impl TraceWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<TraceWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut w = BufWriter::new(File::create(&path)?);
        let hdr = Json::obj(vec![
            ("v", Json::num(TRACE_VERSION as f64)),
            ("ev", Json::str("trace_begin")),
        ]);
        writeln!(w, "{hdr}")?;
        Ok(TraceWriter { w, path, lines: 0 })
    }

    pub fn write(&mut self, st: &Stamped) -> Result<()> {
        writeln!(self.w, "{}", encode_line(st))?;
        self.lines += 1;
        Ok(())
    }

    /// Write the footer (with the bus's totals) and flush.
    pub fn finish(mut self, published: u64, dropped: u64) -> Result<()> {
        let ftr = Json::obj(vec![
            ("v", Json::num(TRACE_VERSION as f64)),
            ("ev", Json::str("trace_end")),
            ("published", Json::num(published as f64)),
            ("dropped", Json::num(dropped as f64)),
        ]);
        writeln!(self.w, "{ftr}")?;
        self.w.flush()?;
        Ok(())
    }
}

/// Read a whole JSONL trace; returns the events plus the footer's
/// reported drop count (0 if the footer is missing). Lines with event
/// kinds this reader does not know (a trace from a newer binary) are
/// skipped, not errors — their `seq` numbers are only needed by
/// [`validate`], which does its own pass.
pub fn read_trace(path: impl AsRef<Path>) -> Result<(Vec<Stamped>, u64)> {
    let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
        format!("reading trace {}", path.as_ref().display())
    })?;
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match decode_record(line)? {
            TraceLine::Event(st) => events.push(st),
            TraceLine::Unknown { .. } => {}
            TraceLine::Envelope(j) => {
                if let Some(d) = j.opt("dropped") {
                    dropped = d.as_usize()? as u64;
                }
            }
        }
    }
    Ok((events, dropped))
}

/// Schema check: every line parses, sequence numbers strictly
/// increase, and total gaps do not exceed the reported drops. Returns
/// (events, gaps, dropped) for reporting. Unknown event kinds still
/// count toward the audit — their lines carry a valid `seq`, so a
/// trace recorded by a newer binary validates cleanly on an older
/// reader instead of hard-failing (forward compatibility).
pub fn validate(path: impl AsRef<Path>) -> Result<(usize, u64, u64)> {
    let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
        format!("reading trace {}", path.as_ref().display())
    })?;
    let mut dropped = 0u64;
    let mut n_events = 0usize;
    let mut gaps = 0u64;
    let mut prev: Option<u64> = None;
    let mut audit = |seq: u64| -> Result<()> {
        if let Some(p) = prev {
            if seq <= p {
                bail!("seq not increasing: {seq} after {p}");
            }
            gaps += seq - p - 1;
        } else {
            gaps += seq;
        }
        prev = Some(seq);
        Ok(())
    };
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match decode_record(line)? {
            TraceLine::Event(st) => {
                audit(st.seq)?;
                n_events += 1;
            }
            TraceLine::Unknown { seq, .. } => {
                audit(seq)?;
                n_events += 1;
            }
            TraceLine::Envelope(j) => {
                if let Some(d) = j.opt("dropped") {
                    dropped = d.as_usize()? as u64;
                }
            }
        }
    }
    if gaps > dropped {
        bail!("trace has {gaps} seq gaps but only {dropped} \
               reported drops");
    }
    Ok((n_events, gaps, dropped))
}

/// Export a recorded trace as a Chrome trace (about://tracing /
/// Perfetto). Collectives become complete-event spans per worker
/// (tid = rank + 1), steps become spans on tid 0, and losses become
/// counter samples.
pub fn chrome_trace(events: &[Stamped]) -> Json {
    let mut out = Vec::new();
    let span = |name: String, ts: f64, dur: f64, tid: u64| {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("X")),
            ("ts", Json::num(ts)),
            ("dur", Json::num(dur.max(0.001))),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid as f64)),
        ])
    };
    // Pair step begin/end on tid 0.
    let mut step_begin: Vec<(u64, f64)> = Vec::new();
    // Open collectives keyed by (rank, bucket, class).
    let mut open: Vec<((usize, usize, &'static str), f64)> = Vec::new();
    for st in events {
        match &st.event {
            Event::StepBegin { step, .. } => {
                step_begin.push((*step, st.t_us));
            }
            Event::StepEnd { step, .. } => {
                if let Some(pos) =
                    step_begin.iter().position(|(s, _)| s == step)
                {
                    let (_, ts) = step_begin.remove(pos);
                    out.push(span(format!("step {step}"), ts,
                                  st.t_us - ts, 0));
                }
            }
            Event::CollectiveLaunched { rank, bucket, class, .. } => {
                open.push(((*rank, *bucket, class), st.t_us));
            }
            Event::CollectiveLanded { rank, bucket, class, .. } => {
                let key = (*rank, *bucket, *class);
                if let Some(pos) =
                    open.iter().position(|(k, _)| *k == key)
                {
                    let (_, ts) = open.remove(pos);
                    out.push(span(
                        format!("{class} b{bucket}"),
                        ts,
                        st.t_us - ts,
                        (*rank + 1) as u64,
                    ));
                }
            }
            Event::LossReported { rank, loss, .. } if *rank < 0 => {
                out.push(Json::obj(vec![
                    ("name", Json::str("loss")),
                    ("ph", Json::str("C")),
                    ("ts", Json::num(st.t_us)),
                    ("pid", Json::num(0.0)),
                    ("args", Json::obj(vec![
                        ("loss", Json::num(*loss)),
                    ])),
                ]));
            }
            _ => {}
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Stamped> {
        let evs = vec![
            Event::StepBegin { step: 1, n_micro: 2, workers: 2 },
            Event::BucketReady { step: 1, bucket: 0, spans: 3,
                                 elems: 256 },
            Event::CollectiveLaunched {
                step: 1, rank: 0, bucket: 0, class: "grad_scatter",
                bytes: 1024,
            },
            Event::Message { rank: 0, class: "grad_scatter",
                             bytes: 512 },
            Event::CollectiveLanded {
                step: 1, rank: 0, bucket: 0, class: "grad_scatter",
                bytes: 1024, ns: 5_000.0,
            },
            Event::ShardStepped { step: 1, rank: 0, bucket: 0,
                                  lo: 0, hi: 64 },
            Event::LossReported { step: 1, rank: -1, loss: 1.25,
                                  lr: 1e-3 },
            Event::CheckpointSaved { step: 1, path: "x/ck".into() },
            Event::ArtifactLoaded { name: "bigram/fwd".into(),
                                    ms: 3.5 },
            Event::RetrySent { rank: 1, peer: 2, class: "grad_reduce",
                               seq: 17, attempt: 2, bytes: 4096 },
            Event::CommTimeout { rank: 1, peer: 2,
                                 class: "grad_reduce", seq: 18,
                                 attempts: 10 },
            Event::CommHangup { step: 1, rank: 3 },
            Event::BucketCompressed {
                step: 1, rank: 0, bucket: -1, codec: "topk",
                raw_bytes: 4096, wire_bytes: 2056,
            },
            Event::ResidualNorm { step: 1, rank: 0, norm: 0.75 },
            Event::JobQueued { job: 4, tenant: "t0".into(),
                               kind: "sft".into(), round: 0 },
            Event::JobStarted { job: 4, tenant: "t0".into(),
                                lease: 1, round: 2 },
            Event::JobPreempted { job: 4, tenant: "t0".into(),
                                  at_step: 6, round: 3 },
            Event::JobFinished { job: 4, tenant: "t0".into(),
                                 outcome: "done".into(), steps: 12,
                                 rounds: 7 },
        ];
        evs.into_iter()
            .enumerate()
            .map(|(i, event)| Stamped {
                seq: i as u64,
                t_us: i as f64 * 10.0,
                event,
            })
            .collect()
    }

    #[test]
    fn jsonl_roundtrip() {
        for st in sample_events() {
            let line = encode_line(&st);
            let back = decode_line(&line).unwrap().unwrap();
            assert_eq!(back, st, "roundtrip failed for {line}");
        }
    }

    #[test]
    fn write_read_validate() {
        let dir = std::env::temp_dir().join("adam_mini_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let mut w = TraceWriter::create(&path).unwrap();
        let evs = sample_events();
        for st in &evs {
            w.write(st).unwrap();
        }
        w.finish(evs.len() as u64, 0).unwrap();
        let (read, dropped) = read_trace(&path).unwrap();
        assert_eq!(read, evs);
        assert_eq!(dropped, 0);
        let (n, gaps, d) = validate(&path).unwrap();
        assert_eq!((n, gaps, d), (evs.len(), 0, 0));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn validate_rejects_unreported_gaps() {
        let dir = std::env::temp_dir().join("adam_mini_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("gap.jsonl");
        let mut w = TraceWriter::create(&path).unwrap();
        let mut evs = sample_events();
        evs.remove(3); // unreported gap in seq
        for st in &evs {
            w.write(st).unwrap();
        }
        w.finish(9, 0).unwrap();
        assert!(validate(&path).is_err());
        // The same gap with a matching drop count is fine.
        let path2 = dir.join("gap_ok.jsonl");
        let mut w = TraceWriter::create(&path2).unwrap();
        for st in &evs {
            w.write(st).unwrap();
        }
        w.finish(9, 1).unwrap();
        assert!(validate(&path2).is_ok());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unknown_kinds_tolerated_with_seq_audit() {
        let dir = std::env::temp_dir().join("adam_mini_trace_fwd");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.jsonl");
        // A trace from a "future" writer: one known event plus two
        // kinds this reader has never heard of, consecutive seqs.
        let lines = [
            r#"{"ev":"trace_begin","v":1}"#.to_string(),
            encode_line(&Stamped {
                seq: 0,
                t_us: 1.0,
                event: Event::StepBegin { step: 1, n_micro: 1,
                                          workers: 1 },
            }),
            r#"{"ev":"job_migrated","seq":1,"t_us":2.0,"v":1}"#
                .to_string(),
            r#"{"ev":"lease_revoked","seq":2,"t_us":3.0,"v":1}"#
                .to_string(),
            r#"{"dropped":0,"ev":"trace_end","published":3,"v":1}"#
                .to_string(),
        ];
        std::fs::write(&path, lines.join("\n")).unwrap();
        // read_trace skips the unknowns; validate audits their seqs
        // (no false gaps) and passes.
        let (evs, dropped) = read_trace(&path).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(dropped, 0);
        let (n, gaps, d) = validate(&path).unwrap();
        assert_eq!((n, gaps, d), (3, 0, 0));
        // An unknown line that *hides* a gap still fails the audit.
        let bad = path.with_file_name("future_gap.jsonl");
        let mut l2 = lines.to_vec();
        l2.remove(2); // seq 1 vanishes, footer still claims 0 drops
        std::fs::write(&bad, l2.join("\n")).unwrap();
        assert!(validate(&bad).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn chrome_export_pairs_spans() {
        let j = chrome_trace(&sample_events());
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // One collective span + one loss counter (no StepEnd in the
        // sample, so no step span).
        let spans: Vec<_> = evs
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str().unwrap() == "X"
            })
            .collect();
        assert_eq!(spans.len(), 1);
        let s = spans[0];
        assert_eq!(s.get("name").unwrap().as_str().unwrap(),
                   "grad_scatter b0");
        assert_eq!(s.get("tid").unwrap().as_usize().unwrap(), 1);
        assert!(s.get("dur").unwrap().as_f64().unwrap() > 0.0);
    }
}
