//! Telemetry: event bus, metrics registry, trace export, and the
//! `repro top` operator console.
//!
//! The [`EventBus`] is the single seam between the training stack
//! and every observer: publishers (`dist::worker`, `dist::comm`,
//! `coordinator::trainer`, `runtime::engine`) call
//! `bus.publish(Event::..)` on the hot path (never blocking; see
//! `event.rs` for the drop policy), and one [`Telemetry`] pump drains
//! the bus, folding each event into the [`MetricsRegistry`] and, when
//! tracing, appending it to a JSONL [`TraceWriter`]. DESIGN.md's
//! "Telemetry" section documents the taxonomy and schema versioning.

pub mod event;
pub mod metrics;
pub mod top;
pub mod trace;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

pub use event::{Event, EventBus, Stamped};
pub use metrics::MetricsRegistry;
pub use trace::TraceWriter;

use crate::util::json::Json;

/// Default bus capacity: large enough that a well-pumped training
/// run never drops, small enough to bound memory if nobody drains.
pub const DEFAULT_BUS_CAPACITY: usize = 65_536;

/// The subscriber half: owns the registry and optional trace sink,
/// drains the shared bus. Publishers only ever see the `Arc<EventBus>`.
pub struct Telemetry {
    bus: Arc<EventBus>,
    pub metrics: MetricsRegistry,
    trace: Option<TraceWriter>,
}

impl Telemetry {
    pub fn new(capacity: usize) -> Telemetry {
        Telemetry {
            bus: EventBus::new(capacity),
            metrics: MetricsRegistry::new(),
            trace: None,
        }
    }

    /// Telemetry that also records every pumped event to a JSONL
    /// trace at `path`.
    pub fn with_trace(capacity: usize, path: impl AsRef<Path>)
        -> Result<Telemetry> {
        let mut t = Telemetry::new(capacity);
        t.trace = Some(TraceWriter::create(path)?);
        Ok(t)
    }

    /// The shared publisher handle to attach to trainers/engines.
    pub fn bus(&self) -> Arc<EventBus> {
        Arc::clone(&self.bus)
    }

    /// Drain everything buffered on the bus into the registry (and
    /// the trace, if recording). Returns the number of events pumped.
    pub fn pump(&mut self) -> Result<usize> {
        let batch = self.bus.drain();
        for st in &batch {
            self.metrics.observe(st);
            if let Some(w) = &mut self.trace {
                w.write(st)?;
            }
        }
        self.metrics.bus_dropped = self.bus.dropped();
        Ok(batch.len())
    }

    /// Final pump + trace footer. Returns the finished trace path, if
    /// one was recording. Safe to call once through an
    /// `Arc<Mutex<Telemetry>>` (consumes only the writer, not self).
    pub fn finish_mut(&mut self) -> Result<Option<PathBuf>> {
        self.pump()?;
        match self.trace.take() {
            Some(w) => {
                let path = w.path.clone();
                w.finish(self.bus.published(), self.bus.dropped())?;
                Ok(Some(path))
            }
            None => Ok(None),
        }
    }
}

/// Export `<trace>.jsonl` as a sibling `<trace>.chrome.json` for
/// about://tracing; returns the written path.
pub fn export_chrome(jsonl: impl AsRef<Path>) -> Result<PathBuf> {
    let jsonl = jsonl.as_ref();
    let (events, _dropped) = trace::read_trace(jsonl)?;
    let out = jsonl.with_extension("chrome.json");
    std::fs::write(&out, trace::chrome_trace(&events).to_string())?;
    Ok(out)
}

/// One-line textual summary of a validated trace (CI schema check).
pub fn check_report(path: impl AsRef<Path>) -> Result<String> {
    let (n, gaps, dropped) = trace::validate(&path)?;
    Ok(format!(
        "trace ok: {n} events, {gaps} seq gaps <= {dropped} \
         reported drops"
    ))
}

/// Machine-readable bus health snapshot.
pub fn bus_to_json(bus: &EventBus) -> Json {
    Json::obj(vec![
        ("published", Json::num(bus.published() as f64)),
        ("dropped", Json::num(bus.dropped() as f64)),
        ("capacity", Json::num(bus.capacity() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pump_feeds_metrics_and_trace() {
        let dir = std::env::temp_dir().join("adam_mini_telemetry_mod");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pump.jsonl");
        let mut t = Telemetry::with_trace(128, &path).unwrap();
        let bus = t.bus();
        bus.publish(Event::StepBegin { step: 1, n_micro: 1, workers: 2 });
        bus.publish(Event::LossReported {
            step: 1, rank: -1, loss: 0.5, lr: 1e-3,
        });
        assert_eq!(t.pump().unwrap(), 2);
        assert_eq!(t.metrics.loss_series, vec![0.5]);
        let trace_path = t.finish_mut().unwrap().unwrap();
        assert_eq!(trace_path, path);
        let (n, gaps, dropped) = trace::validate(&path).unwrap();
        assert_eq!((n, gaps, dropped), (2, 0, 0));
        let chrome = export_chrome(&path).unwrap();
        let text = std::fs::read_to_string(chrome).unwrap();
        assert!(text.contains("traceEvents"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn finish_without_trace_is_none() {
        let mut t = Telemetry::new(8);
        t.bus().publish(Event::StepEnd { step: 1, wall_ns: 10.0 });
        assert!(t.finish_mut().unwrap().is_none());
        assert_eq!(t.metrics.counter("steps_done"), 1);
    }
}
