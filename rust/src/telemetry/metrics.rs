//! Metrics registry: named counters/gauges/histograms fed by the
//! event bus, plus per-worker and cluster-wide aggregates consumed by
//! the `repro top` dashboard and `results/report.json`.
//!
//! Histograms use fixed log2-spaced buckets: `observe` is O(buckets)
//! worst-case but allocation-free, and p50/p95 come from the
//! cumulative counts (quantiles are bucket upper bounds, i.e. exact
//! to within one bucket; `max` is tracked exactly).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::event::{Event, Stamped};

/// Fixed-bucket histogram over positive values.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Upper bound of each bucket (log2-spaced). Values above the
    /// last bound land in the last bucket.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    /// Buckets with upper bounds `lo * 2^i` for `i in 0..n`.
    pub fn log2(lo: f64, n: usize) -> Histogram {
        let bounds = (0..n).map(|i| lo * (1u64 << i) as f64).collect();
        Histogram { bounds, counts: vec![0; n], count: 0, sum: 0.0, max: 0.0 }
    }

    /// Default nanosecond histogram: 64 ns .. ~36 s in 30 buckets.
    pub fn ns() -> Histogram {
        Histogram::log2(64.0, 30)
    }

    pub fn observe(&mut self, v: f64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len() - 1);
        self.counts[i] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Upper bound of the bucket holding quantile `q` in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return self.bounds[i].min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.p50())),
            ("p95", Json::num(self.p95())),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Per-bucket collective progress within the current step, one lane
/// cell per (worker, bucket). States are ordered; a lane only ever
/// advances within a step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LaneState {
    Launched,
    Landed,
    Stepped,
    Gathered,
}

impl LaneState {
    pub fn glyph(&self) -> char {
        match self {
            LaneState::Launched => '~',
            LaneState::Landed => '=',
            LaneState::Stepped => '+',
            LaneState::Gathered => '#',
        }
    }
}

/// Rolling per-worker aggregate.
#[derive(Debug, Clone, Default)]
pub struct WorkerStat {
    pub step: u64,
    pub loss: Option<f64>,
    /// Bytes sent per traffic class (from `Event::Message`, so this
    /// matches the transport ledger exactly).
    pub bytes: BTreeMap<String, u64>,
    pub messages: u64,
    pub collectives: u64,
    pub shard_steps: u64,
    /// Dense f32 bytes that entered the codec (from
    /// `Event::BucketCompressed`); zero when `compress=none`.
    pub comp_raw: u64,
    /// Wire bytes those payloads shrank to.
    pub comp_wire: u64,
    /// Latest error-feedback residual L2 norm, if the codec keeps one.
    pub residual_norm: Option<f64>,
}

impl WorkerStat {
    pub fn total_bytes(&self) -> u64 {
        self.bytes.values().sum()
    }

    /// wire/raw compression ratio, or `None` before any coded
    /// collective has landed.
    pub fn comp_ratio(&self) -> Option<f64> {
        if self.comp_raw == 0 {
            None
        } else {
            Some(self.comp_wire as f64 / self.comp_raw as f64)
        }
    }
}

/// Rolling per-tenant aggregate (serve subsystem), fed by the
/// `Job*` events.
#[derive(Debug, Clone, Default)]
pub struct TenantStat {
    pub queued: u64,
    pub running: u64,
    pub preempted: u64,
    pub done: u64,
    pub failed: u64,
    /// Optimizer steps completed across this tenant's finished jobs.
    pub steps: u64,
    /// Scheduler rounds from arrival to completion, summed over
    /// finished jobs (mean latency = rounds / terminal jobs).
    pub rounds: u64,
    /// Job id and kind of the last job observed for this tenant.
    pub last_job: u64,
    pub last_kind: String,
}

impl TenantStat {
    pub fn terminal(&self) -> u64 {
        self.done + self.failed
    }

    /// Mean completion latency in scheduler rounds.
    pub fn mean_rounds(&self) -> f64 {
        if self.terminal() == 0 {
            0.0
        } else {
            self.rounds as f64 / self.terminal() as f64
        }
    }
}

/// Cap on the retained cluster-loss series (sparkline source).
const LOSS_SERIES_CAP: usize = 512;

/// The registry: subscribe with [`MetricsRegistry::observe`], read
/// aggregates from the public fields / accessors.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    /// Per-worker rolling aggregates, keyed by rank.
    pub workers: BTreeMap<usize, WorkerStat>,
    /// Cluster-mean loss per step (rank == -1 reports), capped.
    pub loss_series: Vec<f64>,
    /// Buckets announced ready in the current step: bucket -> elems.
    pub ready_buckets: BTreeMap<usize, usize>,
    /// Current-step collective lanes: (rank, bucket) -> state.
    pub lanes: BTreeMap<(usize, usize), LaneState>,
    /// Most recent StepBegin payload.
    pub last_step: u64,
    pub n_micro: usize,
    pub world: usize,
    /// Events dropped by the bus (set by the pump, not from events).
    pub bus_dropped: u64,
    /// Last checkpoint path, if any.
    pub last_checkpoint: Option<String>,
    /// Per-tenant job aggregates (serve subsystem), keyed by tenant
    /// id.
    pub tenants: BTreeMap<String, TenantStat>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn hist(&mut self, name: &str) -> &mut Histogram {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(Histogram::ns)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    fn worker(&mut self, rank: usize) -> &mut WorkerStat {
        self.workers.entry(rank).or_default()
    }

    fn tenant(&mut self, id: &str) -> &mut TenantStat {
        self.tenants.entry(id.to_string()).or_default()
    }

    fn lane_advance(&mut self, rank: usize, bucket: i64, s: LaneState) {
        if bucket < 0 {
            return;
        }
        let cell =
            self.lanes.entry((rank, bucket as usize)).or_insert(s);
        if s > *cell {
            *cell = s;
        }
    }

    /// Fold one stamped event into the aggregates.
    pub fn observe(&mut self, st: &Stamped) {
        match &st.event {
            Event::StepBegin { step, n_micro, workers } => {
                self.counter_add("steps_begun", 1);
                self.last_step = *step;
                self.n_micro = *n_micro;
                self.world = (*workers).max(self.world);
                self.ready_buckets.clear();
                self.lanes.clear();
            }
            Event::StepEnd { wall_ns, .. } => {
                self.counter_add("steps_done", 1);
                self.hist("step_wall_ns").observe(*wall_ns);
                self.gauge_set("last_step_wall_ns", *wall_ns);
            }
            Event::BucketReady { bucket, elems, .. } => {
                self.counter_add("buckets_ready", 1);
                self.ready_buckets.insert(*bucket, *elems);
            }
            Event::CollectiveLaunched { rank, bucket, .. } => {
                self.counter_add("collectives_launched", 1);
                self.lane_advance(*rank, *bucket as i64,
                                  LaneState::Launched);
            }
            Event::CollectiveLanded { rank, bucket, class, ns, .. } => {
                self.counter_add("collectives_landed", 1);
                let lane = if *class == "param_gather" {
                    LaneState::Gathered
                } else {
                    LaneState::Landed
                };
                self.lane_advance(*rank, *bucket as i64, lane);
                self.hist("collective_ns").observe(*ns);
                let key = format!("collective_ns/{class}");
                self.hist(&key).observe(*ns);
                self.worker(*rank).collectives += 1;
            }
            Event::ShardStepped { rank, bucket, .. } => {
                self.counter_add("shard_steps", 1);
                self.lane_advance(*rank, *bucket, LaneState::Stepped);
                self.worker(*rank).shard_steps += 1;
            }
            Event::LossReported { step, rank, loss, lr } => {
                if *rank < 0 {
                    if self.loss_series.len() >= LOSS_SERIES_CAP {
                        self.loss_series.remove(0);
                    }
                    self.loss_series.push(*loss);
                    self.gauge_set("loss", *loss);
                    self.gauge_set("lr", *lr);
                } else {
                    let w = self.worker(*rank as usize);
                    w.loss = Some(*loss);
                    w.step = *step;
                }
            }
            Event::CheckpointSaved { path, .. } => {
                self.counter_add("checkpoints", 1);
                self.last_checkpoint = Some(path.clone());
            }
            Event::Message { rank, class, bytes } => {
                self.counter_add("messages", 1);
                let w = self.worker(*rank);
                w.messages += 1;
                *w.bytes.entry(class.to_string()).or_insert(0) += bytes;
            }
            Event::ArtifactLoaded { ms, .. } => {
                self.counter_add("artifacts_loaded", 1);
                self.hist("artifact_load_ns").observe(ms * 1e6);
            }
            // Counter only: the retried bytes already arrive via
            // Event::Message under the "retry" class, so adding them
            // here would double-count the ledger.
            Event::RetrySent { .. } => {
                self.counter_add("retries", 1);
            }
            Event::CommTimeout { .. } => {
                self.counter_add("comm_timeouts", 1);
            }
            Event::CommHangup { .. } => {
                self.counter_add("comm_hangups", 1);
            }
            Event::BucketCompressed { rank, raw_bytes, wire_bytes,
                                      .. } => {
                self.counter_add("buckets_compressed", 1);
                let w = self.worker(*rank);
                w.comp_raw += raw_bytes;
                w.comp_wire += wire_bytes;
            }
            Event::ResidualNorm { rank, norm, .. } => {
                let w = self.worker(*rank);
                w.residual_norm = Some(*norm);
            }
            Event::JobQueued { job, tenant, kind, .. } => {
                self.counter_add("jobs_queued", 1);
                let t = self.tenant(tenant);
                t.queued += 1;
                t.last_job = *job;
                t.last_kind = kind.clone();
            }
            Event::JobStarted { job, tenant, .. } => {
                self.counter_add("jobs_started", 1);
                let t = self.tenant(tenant);
                t.running += 1;
                t.last_job = *job;
            }
            Event::JobPreempted { job, tenant, .. } => {
                self.counter_add("jobs_preempted", 1);
                let t = self.tenant(tenant);
                t.preempted += 1;
                t.running = t.running.saturating_sub(1);
                t.last_job = *job;
            }
            Event::JobFinished { job, tenant, outcome, steps,
                                 rounds } => {
                self.counter_add("jobs_finished", 1);
                let t = self.tenant(tenant);
                if outcome == "failed" {
                    t.failed += 1;
                } else {
                    t.done += 1;
                }
                t.running = t.running.saturating_sub(1);
                t.steps += steps;
                t.rounds += rounds;
                t.last_job = *job;
            }
        }
    }

    /// Cluster bytes per class, summed over workers.
    pub fn cluster_bytes(&self) -> BTreeMap<String, u64> {
        let mut out: BTreeMap<String, u64> = BTreeMap::new();
        for w in self.workers.values() {
            for (class, b) in &w.bytes {
                *out.entry(class.clone()).or_insert(0) += b;
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v)))
                .collect(),
        );
        let hists = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| (k.clone(), h.to_json()))
                .collect(),
        );
        let workers = Json::Arr(
            self.workers
                .iter()
                .map(|(rank, w)| {
                    Json::obj(vec![
                        ("rank", Json::num(*rank as f64)),
                        ("step", Json::num(w.step as f64)),
                        ("loss",
                         w.loss.map(Json::Num).unwrap_or(Json::Null)),
                        ("bytes", Json::Obj(
                            w.bytes
                                .iter()
                                .map(|(c, b)| {
                                    (c.clone(), Json::num(*b as f64))
                                })
                                .collect(),
                        )),
                        ("messages", Json::num(w.messages as f64)),
                        ("collectives", Json::num(w.collectives as f64)),
                        ("shard_steps",
                         Json::num(w.shard_steps as f64)),
                        ("comp_raw", Json::num(w.comp_raw as f64)),
                        ("comp_wire", Json::num(w.comp_wire as f64)),
                        ("residual_norm",
                         w.residual_norm.map(Json::Num)
                             .unwrap_or(Json::Null)),
                    ])
                })
                .collect(),
        );
        let tenants = Json::Obj(
            self.tenants
                .iter()
                .map(|(id, t)| {
                    (id.clone(), Json::obj(vec![
                        ("queued", Json::num(t.queued as f64)),
                        ("running", Json::num(t.running as f64)),
                        ("preempted", Json::num(t.preempted as f64)),
                        ("done", Json::num(t.done as f64)),
                        ("failed", Json::num(t.failed as f64)),
                        ("steps", Json::num(t.steps as f64)),
                        ("mean_rounds", Json::num(t.mean_rounds())),
                    ]))
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
            ("workers", workers),
            ("tenants", tenants),
            ("loss_series", Json::arr_f64(&self.loss_series)),
            ("bus_dropped", Json::num(self.bus_dropped as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stamp(seq: u64, event: Event) -> Stamped {
        Stamped { seq, t_us: seq as f64, event }
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::log2(1.0, 20);
        for v in 1..=100 {
            h.observe(v as f64);
        }
        assert_eq!(h.count(), 100);
        assert!(h.p50() >= 50.0 && h.p50() <= 64.0);
        assert!(h.p95() >= 95.0 && h.p95() <= 128.0);
        assert_eq!(h.max(), 100.0);
        // Overflow goes to the last bucket but max stays exact.
        h.observe(1e12);
        assert_eq!(h.max(), 1e12);
    }

    #[test]
    fn events_aggregate_per_worker() {
        let mut m = MetricsRegistry::new();
        m.observe(&stamp(0, Event::StepBegin {
            step: 1, n_micro: 2, workers: 2,
        }));
        m.observe(&stamp(1, Event::Message {
            rank: 0, class: "grad_reduce", bytes: 128,
        }));
        m.observe(&stamp(2, Event::Message {
            rank: 0, class: "grad_reduce", bytes: 64,
        }));
        m.observe(&stamp(3, Event::LossReported {
            step: 1, rank: 0, loss: 2.5, lr: 1e-3,
        }));
        m.observe(&stamp(4, Event::LossReported {
            step: 1, rank: -1, loss: 2.25, lr: 1e-3,
        }));
        assert_eq!(m.workers[&0].bytes["grad_reduce"], 192);
        assert_eq!(m.workers[&0].loss, Some(2.5));
        assert_eq!(m.loss_series, vec![2.25]);
        assert_eq!(m.cluster_bytes()["grad_reduce"], 192);
    }

    #[test]
    fn lanes_advance_and_reset() {
        let mut m = MetricsRegistry::new();
        m.observe(&stamp(0, Event::CollectiveLaunched {
            step: 1, rank: 0, bucket: 3, class: "grad_scatter",
            bytes: 8,
        }));
        m.observe(&stamp(1, Event::CollectiveLanded {
            step: 1, rank: 0, bucket: 3, class: "grad_scatter",
            bytes: 8, ns: 100.0,
        }));
        assert_eq!(m.lanes[&(0, 3)], LaneState::Landed);
        // A late Launched for the same cell must not regress it.
        m.observe(&stamp(2, Event::CollectiveLaunched {
            step: 1, rank: 0, bucket: 3, class: "param_gather",
            bytes: 8,
        }));
        assert_eq!(m.lanes[&(0, 3)], LaneState::Landed);
        m.observe(&stamp(3, Event::StepBegin {
            step: 2, n_micro: 1, workers: 1,
        }));
        assert!(m.lanes.is_empty());
    }

    #[test]
    fn compression_events_aggregate_per_worker() {
        let mut m = MetricsRegistry::new();
        assert_eq!(WorkerStat::default().comp_ratio(), None);
        m.observe(&stamp(0, Event::BucketCompressed {
            step: 1, rank: 0, bucket: -1, codec: "f16",
            raw_bytes: 4000, wire_bytes: 2000,
        }));
        m.observe(&stamp(1, Event::BucketCompressed {
            step: 1, rank: 0, bucket: 2, codec: "f16",
            raw_bytes: 1000, wire_bytes: 500,
        }));
        m.observe(&stamp(2, Event::ResidualNorm {
            step: 1, rank: 0, norm: 0.125,
        }));
        let w = &m.workers[&0];
        assert_eq!((w.comp_raw, w.comp_wire), (5000, 2500));
        assert_eq!(w.comp_ratio(), Some(0.5));
        assert_eq!(w.residual_norm, Some(0.125));
        assert_eq!(m.counter("buckets_compressed"), 2);
        let j = m.to_json();
        let ws = match j.get("workers").unwrap() {
            Json::Arr(v) => v,
            _ => panic!("workers should be an array"),
        };
        assert_eq!(
            ws[0].get("comp_wire").unwrap().as_usize().unwrap(),
            2500
        );
    }

    #[test]
    fn job_events_aggregate_per_tenant() {
        let mut m = MetricsRegistry::new();
        m.observe(&stamp(0, Event::JobQueued {
            job: 1, tenant: "t0".into(), kind: "train".into(),
            round: 0,
        }));
        m.observe(&stamp(1, Event::JobStarted {
            job: 1, tenant: "t0".into(), lease: 0, round: 1,
        }));
        m.observe(&stamp(2, Event::JobPreempted {
            job: 1, tenant: "t0".into(), at_step: 4, round: 2,
        }));
        m.observe(&stamp(3, Event::JobStarted {
            job: 1, tenant: "t0".into(), lease: 1, round: 3,
        }));
        m.observe(&stamp(4, Event::JobFinished {
            job: 1, tenant: "t0".into(), outcome: "done".into(),
            steps: 8, rounds: 4,
        }));
        let t = &m.tenants["t0"];
        assert_eq!((t.queued, t.preempted, t.done, t.failed),
                   (1, 1, 1, 0));
        assert_eq!(t.running, 0);
        assert_eq!(t.steps, 8);
        assert_eq!(t.mean_rounds(), 4.0);
        assert_eq!(t.last_kind, "train");
        assert_eq!(m.counter("jobs_finished"), 1);
        let j = m.to_json();
        assert!(j.get("tenants").unwrap().opt("t0").is_some());
    }

    #[test]
    fn json_snapshot_has_sections() {
        let mut m = MetricsRegistry::new();
        m.counter_add("x", 2);
        m.gauge_set("g", 1.5);
        m.hist("h").observe(100.0);
        let j = m.to_json();
        assert_eq!(
            j.get("counters").unwrap().get("x").unwrap()
                .as_usize().unwrap(),
            2
        );
        assert!(j.get("histograms").unwrap().opt("h").is_some());
    }
}
