//! `repro top` — live operator console.
//!
//! Rendering is a pure function from a [`MetricsRegistry`] snapshot
//! to a `String` frame, so the same code drives the live ANSI
//! dashboard, `--replay <trace> --once` in CI (no TTY: one plain
//! frame on stdout), and unit tests. Only the live loop emits ANSI
//! control codes.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::dist::TrafficClass;

use super::metrics::MetricsRegistry;
use super::trace::read_trace;
use super::Telemetry;

/// Unicode sparkline of a series, rescaled to `width` columns.
pub fn sparkline(series: &[f64], width: usize) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if series.is_empty() || width == 0 {
        return String::new();
    }
    // Downsample by taking the last `width` points.
    let tail = if series.len() > width {
        &series[series.len() - width..]
    } else {
        series
    };
    let lo = tail.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = tail.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    tail.iter()
        .map(|v| {
            let t = ((v - lo) / span * 7.0).round() as usize;
            GLYPHS[t.min(7)]
        })
        .collect()
}

/// Human-readable byte count.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

fn fmt_loss(loss: Option<f64>) -> String {
    match loss {
        Some(l) => format!("{l:.4}"),
        None => "-".to_string(),
    }
}

/// Render one dashboard frame (no ANSI control codes).
pub fn render_frame(m: &MetricsRegistry) -> String {
    let mut out = String::new();
    let world = m.world.max(m.workers.len());
    out.push_str(&format!(
        "repro top — step {}  micro {}  world {}  drops {}\n",
        m.last_step, m.n_micro, world, m.bus_dropped
    ));
    if m.bus_dropped > 0 {
        out.push_str(
            "  !! event bus under backpressure: drops recorded; \
             aggregates remain exact, lanes may skip\n",
        );
    }
    // Cluster loss + sparkline.
    let loss = m.gauge("loss");
    let lr = m.gauge("lr");
    out.push_str(&format!(
        "loss {}  lr {}  {}\n",
        fmt_loss(loss),
        lr.map(|v| format!("{v:.2e}")).unwrap_or_else(|| "-".into()),
        sparkline(&m.loss_series, 48)
    ));
    // Workers table.
    let mut header = vec!["rank".to_string(), "step".to_string(),
                          "loss".to_string()];
    for c in TrafficClass::ALL {
        header.push(c.name().to_string());
    }
    header.push("coll".to_string());
    header.push("msgs".to_string());
    header.push("comp".to_string());
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (rank, w) in &m.workers {
        let mut row = vec![
            format!("w{rank}"),
            format!("{}", w.step),
            fmt_loss(w.loss),
        ];
        for c in TrafficClass::ALL {
            let b = w.bytes.get(c.name()).copied().unwrap_or(0);
            row.push(fmt_bytes(b));
        }
        row.push(format!("{}", w.collectives));
        row.push(format!("{}", w.messages));
        row.push(match w.comp_ratio() {
            Some(r) => format!("{r:.2}x"),
            None => "-".to_string(),
        });
        rows.push(row);
    }
    if rows.is_empty() {
        rows.push(vec!["-".to_string(); header.len()]);
    }
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    out.push_str(&crate::util::csv::ascii_table(&hdr_refs, &rows));
    // Per-worker collective lanes for the current step.
    let max_bucket =
        m.lanes.keys().map(|(_, b)| *b).max().map(|b| b + 1);
    if let Some(n_buckets) = max_bucket {
        out.push_str(
            "lanes: . pending  ~ launched  = landed  + stepped  \
             # gathered\n",
        );
        let mut ranks: Vec<usize> = m.workers.keys().copied().collect();
        if ranks.is_empty() {
            ranks = m.lanes.keys().map(|(r, _)| *r).collect();
            ranks.dedup();
        }
        for rank in ranks {
            let lane: String = (0..n_buckets)
                .map(|b| {
                    m.lanes
                        .get(&(rank, b))
                        .map(|s| s.glyph())
                        .unwrap_or('.')
                })
                .collect();
            out.push_str(&format!("w{rank} [{lane}]\n"));
        }
    }
    // Tenants table (serve subsystem), only when Job* events flowed.
    if !m.tenants.is_empty() {
        let hdr = ["tenant", "queued", "run", "preempt", "done",
                   "failed", "steps", "avg rounds", "last job"];
        let rows: Vec<Vec<String>> = m
            .tenants
            .iter()
            .map(|(id, t)| {
                vec![
                    id.clone(),
                    format!("{}", t.queued),
                    format!("{}", t.running),
                    format!("{}", t.preempted),
                    format!("{}", t.done),
                    format!("{}", t.failed),
                    format!("{}", t.steps),
                    format!("{:.1}", t.mean_rounds()),
                    if t.last_kind.is_empty() {
                        format!("#{}", t.last_job)
                    } else {
                        format!("#{} {}", t.last_job, t.last_kind)
                    },
                ]
            })
            .collect();
        out.push_str(&format!(
            "tenants {}  jobs done {}  preemptions {}\n",
            m.tenants.len(),
            m.counter("jobs_finished"),
            m.counter("jobs_preempted")
        ));
        out.push_str(&crate::util::csv::ascii_table(&hdr, &rows));
    }
    // Latency digest.
    let steps = m.counter("steps_done");
    if steps > 0 {
        out.push_str(&format!("steps done {steps}"));
        if let Some(w) = m.gauge("last_step_wall_ns") {
            out.push_str(&format!("  last step {:.2} ms", w / 1e6));
        }
        out.push('\n');
    }
    if let Some(ck) = &m.last_checkpoint {
        out.push_str(&format!("checkpoint: {ck}\n"));
    }
    out
}

/// Live console loop (runs on its own thread): pump + render the
/// shared telemetry every `interval_ms` until `done` flips, then
/// leave one final frame. Uses `try_lock` so it never stalls the
/// training thread's per-step pump.
pub fn live_loop(tel: &Arc<Mutex<Telemetry>>, done: &AtomicBool,
                 interval_ms: u64) {
    loop {
        if done.load(Ordering::Relaxed) {
            let mut t = tel.lock().unwrap_or_else(|e| e.into_inner());
            let _ = t.pump();
            print!("\x1b[2J\x1b[H{}", render_frame(&t.metrics));
            let _ = std::io::stdout().flush();
            break;
        }
        if let Ok(mut t) = tel.try_lock() {
            let _ = t.pump();
            print!("\x1b[2J\x1b[H{}", render_frame(&t.metrics));
            let _ = std::io::stdout().flush();
        }
        std::thread::sleep(std::time::Duration::from_millis(
            interval_ms.max(16)));
    }
    println!();
}

/// Build a registry by folding a recorded trace, then return it with
/// the footer's drop count applied.
pub fn registry_from_trace(path: impl AsRef<Path>)
    -> Result<MetricsRegistry> {
    let (events, dropped) = read_trace(path)?;
    let mut m = MetricsRegistry::new();
    for st in &events {
        m.observe(st);
    }
    m.bus_dropped = dropped;
    Ok(m)
}

/// Replay a recorded trace: `once=true` prints a single plain frame
/// (CI / no TTY); otherwise frames are re-rendered event-by-event
/// with ANSI clear codes at ~`interval_ms` cadence.
pub fn replay(path: impl AsRef<Path>, once: bool, interval_ms: u64)
    -> Result<()> {
    if once {
        let m = registry_from_trace(path)?;
        print!("{}", render_frame(&m));
        return Ok(());
    }
    let (events, dropped) = read_trace(&path)?;
    let mut m = MetricsRegistry::new();
    m.bus_dropped = dropped;
    let chunk = (events.len() / 60).max(1);
    for (i, st) in events.iter().enumerate() {
        m.observe(st);
        if i % chunk == 0 || i + 1 == events.len() {
            print!("\x1b[2J\x1b[H{}", render_frame(&m));
            std::thread::sleep(
                std::time::Duration::from_millis(interval_ms),
            );
        }
    }
    println!("replay done: {} events", events.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::event::{Event, Stamped};

    #[test]
    fn sparkline_scales() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0], 8);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
        assert_eq!(sparkline(&[], 8), "");
        // Constant series stays at the floor glyph.
        assert_eq!(sparkline(&[5.0, 5.0], 8), "▁▁");
    }

    #[test]
    fn bytes_humanize() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MB");
    }

    #[test]
    fn frame_renders_workers_and_lanes() {
        let mut m = MetricsRegistry::new();
        let mut feed = |seq: u64, event: Event| {
            m.observe(&Stamped { seq, t_us: seq as f64, event });
        };
        feed(0, Event::StepBegin { step: 3, n_micro: 2, workers: 2 });
        feed(1, Event::Message {
            rank: 0, class: "grad_scatter", bytes: 4096,
        });
        feed(2, Event::LossReported {
            step: 3, rank: 0, loss: 1.5, lr: 1e-3,
        });
        feed(3, Event::LossReported {
            step: 3, rank: -1, loss: 1.5, lr: 1e-3,
        });
        feed(4, Event::CollectiveLaunched {
            step: 3, rank: 0, bucket: 1, class: "grad_scatter",
            bytes: 4096,
        });
        let frame = render_frame(&m);
        assert!(frame.contains("step 3"));
        assert!(frame.contains("w0"));
        assert!(frame.contains("4.0 KB"));
        assert!(frame.contains("1.5000"));
        assert!(frame.contains("[.~]"), "lane row missing: {frame}");
        assert!(!frame.contains('\x1b'), "plain frame must be ANSI-free");
    }

    #[test]
    fn frame_shows_compression_ratio_when_coded() {
        let mut m = MetricsRegistry::new();
        let mut feed = |seq: u64, event: Event| {
            m.observe(&Stamped { seq, t_us: seq as f64, event });
        };
        feed(0, Event::StepBegin { step: 1, n_micro: 1, workers: 1 });
        feed(1, Event::BucketCompressed {
            step: 1, rank: 0, bucket: -1, codec: "f16",
            raw_bytes: 8000, wire_bytes: 4000,
        });
        feed(2, Event::ResidualNorm { step: 1, rank: 0, norm: 0.1 });
        let frame = render_frame(&m);
        assert!(frame.contains("comp"), "{frame}");
        assert!(frame.contains("0.50x"), "{frame}");
    }

    #[test]
    fn empty_registry_still_renders() {
        let frame = render_frame(&MetricsRegistry::new());
        assert!(frame.contains("repro top"));
        // No Job* events → no tenants section.
        assert!(!frame.contains("tenants"));
    }

    #[test]
    fn frame_renders_tenants_table() {
        let mut m = MetricsRegistry::new();
        let mut feed = |seq: u64, event: Event| {
            m.observe(&Stamped { seq, t_us: seq as f64, event });
        };
        feed(0, Event::JobQueued {
            job: 2, tenant: "alice".into(), kind: "eval".into(),
            round: 0,
        });
        feed(1, Event::JobStarted {
            job: 2, tenant: "alice".into(), lease: 0, round: 1,
        });
        feed(2, Event::JobFinished {
            job: 2, tenant: "alice".into(), outcome: "done".into(),
            steps: 3, rounds: 2,
        });
        let frame = render_frame(&m);
        assert!(frame.contains("tenants 1"), "{frame}");
        assert!(frame.contains("alice"));
        assert!(frame.contains("#2 eval"));
        assert!(!frame.contains('\x1b'));
    }
}
