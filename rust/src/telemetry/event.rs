//! Typed telemetry events + the lock-light bounded `EventBus`.
//!
//! Publishers live on the training hot path (worker threads, the ring
//! transport, the trainer loop), so `publish` must never block: it
//! takes the ring lock with `try_lock` and counts a drop on
//! contention instead of waiting. The ring is bounded; when full the
//! oldest event is overwritten (again counted as a drop). Sequence
//! numbers are assigned under the same lock, so a consumer that sees
//! gaps in `seq` can attribute every gap to a reported drop — this is
//! the invariant the CI trace check relies on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::dist::TrafficClass;

/// One telemetry event. Ranks are `i64` so `-1` can mean
/// "cluster-wide" (e.g. the mean loss across workers); `bucket` is
/// `i64` so `-1` can mean "whole shard" (the deferred, non-granular
/// optimizer step).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A training step is starting (driver side).
    StepBegin { step: u64, n_micro: usize, workers: usize },
    /// A training step finished; `wall_ns` is measured wall time.
    StepEnd { step: u64, wall_ns: f64 },
    /// All micro-batch gradients for a bucket have landed; the bucket
    /// is being handed to the worker collectives.
    BucketReady { step: u64, bucket: usize, spans: usize, elems: usize },
    /// A worker is entering a collective for a bucket.
    CollectiveLaunched {
        step: u64,
        rank: usize,
        bucket: usize,
        class: &'static str,
        bytes: u64,
    },
    /// The collective completed; `ns` is measured wall time.
    CollectiveLanded {
        step: u64,
        rank: usize,
        bucket: usize,
        class: &'static str,
        bytes: u64,
        ns: f64,
    },
    /// A worker stepped its optimizer shard (or the shard∩bucket
    /// segment when `bucket == -1` is false).
    ShardStepped { step: u64, rank: usize, bucket: i64, lo: usize, hi: usize },
    /// Loss for one worker (`rank >= 0`) or the cluster mean
    /// (`rank == -1`).
    LossReported { step: u64, rank: i64, loss: f64, lr: f64 },
    /// A run checkpoint was written.
    CheckpointSaved { step: u64, path: String },
    /// One point-to-point transport message (ledger hook). Summing
    /// `bytes` per class reproduces `CommStats` exactly.
    Message { rank: usize, class: &'static str, bytes: u64 },
    /// A compiled artifact was loaded (cache miss) by the engine.
    ArtifactLoaded { name: String, ms: f64 },
    /// The socket transport retransmitted a frame (attempt > 0).
    /// `class` is the base traffic class being carried; the retried
    /// bytes themselves also flow through [`Event::Message`] under
    /// the `retry` class, so metrics must count this event but never
    /// re-add its bytes.
    RetrySent {
        rank: usize,
        peer: usize,
        class: &'static str,
        seq: u64,
        attempt: u64,
        bytes: u64,
    },
    /// A send exhausted its retry budget without an ack.
    CommTimeout {
        rank: usize,
        peer: usize,
        class: &'static str,
        seq: u64,
        attempts: u64,
    },
    /// A worker's comm thread hung up mid-step; the step is being
    /// abandoned with a typed error instead of a crash.
    CommHangup { step: u64, rank: usize },
    /// A coded collective finished on one rank: `raw_bytes` dense f32
    /// payload shrank to `wire_bytes` on the wire. `bucket == -1` is
    /// the batch-path / whole-buffer collective; streamed buckets
    /// carry their bucket index.
    BucketCompressed {
        step: u64,
        rank: usize,
        bucket: i64,
        codec: &'static str,
        raw_bytes: u64,
        wire_bytes: u64,
    },
    /// Post-step L2 norm of one rank's error-feedback residual — the
    /// observable that dropped gradient mass stays bounded instead of
    /// accumulating.
    ResidualNorm { step: u64, rank: usize, norm: f64 },
    /// A serve job entered the scheduler queue (serve subsystem).
    JobQueued { job: u64, tenant: String, kind: String, round: u64 },
    /// A serve job was granted a worker lease and started (or resumed)
    /// running a quantum.
    JobStarted { job: u64, tenant: String, lease: usize, round: u64 },
    /// A serve job was preempted at a step boundary; `at_step` is the
    /// number of optimizer steps it has completed so far.
    JobPreempted { job: u64, tenant: String, at_step: u64, round: u64 },
    /// A serve job reached a terminal state. `outcome` is one of
    /// `done` / `failed`; `steps` counts completed optimizer steps and
    /// `rounds` the scheduler rounds from arrival to completion
    /// (queueing latency in scheduler time).
    JobFinished {
        job: u64,
        tenant: String,
        outcome: String,
        steps: u64,
        rounds: u64,
    },
}

impl Event {
    /// Stable short tag used in JSONL traces and metrics keys.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::StepBegin { .. } => "step_begin",
            Event::StepEnd { .. } => "step_end",
            Event::BucketReady { .. } => "bucket_ready",
            Event::CollectiveLaunched { .. } => "collective_launched",
            Event::CollectiveLanded { .. } => "collective_landed",
            Event::ShardStepped { .. } => "shard_stepped",
            Event::LossReported { .. } => "loss",
            Event::CheckpointSaved { .. } => "checkpoint",
            Event::Message { .. } => "message",
            Event::ArtifactLoaded { .. } => "artifact",
            Event::RetrySent { .. } => "retry_sent",
            Event::CommTimeout { .. } => "comm_timeout",
            Event::CommHangup { .. } => "comm_hangup",
            Event::BucketCompressed { .. } => "bucket_compressed",
            Event::ResidualNorm { .. } => "residual_norm",
            Event::JobQueued { .. } => "job_queued",
            Event::JobStarted { .. } => "job_started",
            Event::JobPreempted { .. } => "job_preempted",
            Event::JobFinished { .. } => "job_finished",
        }
    }
}

/// Map a traffic-class name back to the `&'static str` the enum
/// variants carry (used when reconstructing events from a trace).
pub fn intern_class(name: &str) -> &'static str {
    for c in TrafficClass::ALL {
        if c.name() == name {
            return c.name();
        }
    }
    "unknown"
}

/// Map a codec name back to the `&'static str` the
/// [`Event::BucketCompressed`] variant carries (trace reconstruction,
/// mirroring [`intern_class`]).
pub fn intern_codec(name: &str) -> &'static str {
    match name {
        "f16" => "f16",
        "topk" => "topk",
        "none" => "none",
        _ => "unknown",
    }
}

/// An event stamped with its bus-assigned sequence number and
/// microseconds since the bus was created.
#[derive(Debug, Clone, PartialEq)]
pub struct Stamped {
    pub seq: u64,
    pub t_us: f64,
    pub event: Event,
}

struct Ring {
    buf: VecDeque<Stamped>,
    next_seq: u64,
}

/// Bounded multi-producer event ring. Cheap to clone via `Arc`.
pub struct EventBus {
    inner: Mutex<Ring>,
    dropped: AtomicU64,
    capacity: usize,
    epoch: Instant,
}

impl EventBus {
    pub fn new(capacity: usize) -> Arc<EventBus> {
        Arc::new(EventBus {
            inner: Mutex::new(Ring {
                buf: VecDeque::with_capacity(capacity.max(1)),
                next_seq: 0,
            }),
            dropped: AtomicU64::new(0),
            capacity: capacity.max(1),
            epoch: Instant::now(),
        })
    }

    fn lock(&self) -> MutexGuard<'_, Ring> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish without ever blocking: lock contention or a full ring
    /// both count as drops. Returns true if the event was enqueued.
    pub fn publish(&self, event: Event) -> bool {
        let mut ring = match self.inner.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::WouldBlock) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        };
        if ring.buf.len() >= self.capacity {
            ring.buf.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        let seq = ring.next_seq;
        ring.next_seq += 1;
        let t_us = self.epoch.elapsed().as_secs_f64() * 1e6;
        ring.buf.push_back(Stamped { seq, t_us, event });
        true
    }

    /// Drain everything currently buffered (subscriber side; may
    /// briefly contend with publishers, which then drop).
    pub fn drain(&self) -> Vec<Stamped> {
        let mut ring = self.lock();
        let buf = std::mem::take(&mut ring.buf);
        buf.into()
    }

    /// Total events dropped (full ring or publish contention).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total events ever assigned a sequence number.
    pub fn published(&self) -> u64 {
        self.lock().next_seq
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(step: u64) -> Event {
        Event::StepBegin { step, n_micro: 1, workers: 1 }
    }

    #[test]
    fn seq_is_monotonic() {
        let bus = EventBus::new(16);
        for s in 0..5 {
            bus.publish(ev(s));
        }
        let got = bus.drain();
        let seqs: Vec<u64> = got.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        assert_eq!(bus.dropped(), 0);
    }

    #[test]
    fn full_ring_drops_oldest() {
        let bus = EventBus::new(3);
        for s in 0..7 {
            bus.publish(ev(s));
        }
        let got = bus.drain();
        assert_eq!(got.len(), 3);
        // Newest three survive; four were dropped.
        assert_eq!(got[0].seq, 4);
        assert_eq!(got[2].seq, 6);
        assert_eq!(bus.dropped(), 4);
        assert_eq!(bus.published(), 7);
    }

    #[test]
    fn gaps_bounded_by_drops() {
        let bus = EventBus::new(2);
        for s in 0..10 {
            bus.publish(ev(s));
        }
        let got = bus.drain();
        let mut gaps = 0u64;
        for w in got.windows(2) {
            gaps += w[1].seq - w[0].seq - 1;
        }
        // First surviving seq also implies earlier drops.
        gaps += got.first().map(|s| s.seq).unwrap_or(0);
        assert!(gaps <= bus.dropped());
    }

    #[test]
    fn concurrent_publish_never_blocks() {
        let bus = EventBus::new(8);
        let mut joins = Vec::new();
        for t in 0..4 {
            let b = Arc::clone(&bus);
            joins.push(std::thread::spawn(move || {
                for s in 0..1000 {
                    b.publish(ev(t * 1000 + s));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let survived = bus.drain().len() as u64;
        assert_eq!(survived + bus.dropped(), bus.published());
    }
}
